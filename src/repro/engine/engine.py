"""``repro.engine.Engine`` — the one serving engine.

The paper's claim applied to serving: function *invocation* is one uniform
low-granularity API while *placement and scheduling* are chosen dynamically
as the application progresses. Before this module the repro hard-coded
both: the pre-engine fixed-slot and paged servers each owned an admission
loop, a tick loop, preemption logic, and a metrics dialect. ``Engine``
collapsed them:

* **one submit/admit/step/complete loop** (``tick``) over a pluggable
  sequence-state backend behind the ``SequenceState`` protocol
  (``engine.state``) — ``cache="paged"`` (block pool, chunked prefill,
  preempt-and-recompute), ``cache="slots"`` (fixed-slot contiguous cache,
  single-request prefill, no preemption), or ``cache="recurrent"``
  (constant-size SSM/xLSTM state, chunked prefill, snapshot-eviction);
  ``cache="auto"`` picks the model family's default
  (``registry.default_cache_backend``);
* **pluggable scheduling** — a ``SchedulerPolicy`` object
  (``engine.scheduler``) decides admission order, victim selection, and
  block budgets; ``FIFOPolicy`` reproduces the legacy servers bitwise,
  ``PriorityPolicy``/``SJFPolicy`` reorder admission without touching the
  math;
* **streaming outputs** — ``submit`` returns a ``RequestHandle``
  (``engine.stream``): ``handle.tokens()`` yields tokens as ticks produce
  them, ``handle.on_token`` registers callbacks, so clients no longer need
  ``run_until_drained``;
* **fabric-routed invocation** — the jitted serve steps are registered on
  the step bundle's PR-3 ``Fabric`` (``engine.prefill`` / ``engine.decode``
  / ``engine.paged_step``) and every tick invokes them through
  ``fabric.call(..., placement="local")``; ``metrics()["fabric"]`` reports
  per-step call counts and the resolved placement of each registered step.

The ``runtime/server.py`` deprecation shims over this class have been
removed. See docs/engine.md for the API, the scheduler protocol,
streaming semantics, and the migration table from the legacy servers.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
import warnings
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.configs.base import ModelConfig, RunConfig
from repro.core import transport as transport_lib
from repro.core.costmodel import TransportEstimate
from repro.engine.scheduler import (SchedulerPolicy, SchedulerState,
                                    _PolicyBase, resolve_policy)
from repro.engine.state import (BlockPool, PagedKVState, RecurrentState,
                                SequenceState, SlotKVState)
from repro.engine.stream import RequestHandle
from repro.faults.errors import EngineFailedError
from repro.models import model as model_lib
from repro.models.kvcache import state_to_bytes
from repro.runtime.steps import (make_paged_serve_step,
                                 make_recurrent_serve_step, make_serve_step,
                                 sharding_ctx)

PyTree = Any

__all__ = ["Request", "BlockPool", "Engine", "MigrationTicket"]


@dataclasses.dataclass
class Request:
    """One generation request.

    ``priority`` is read by priority-aware scheduler policies (higher =
    more urgent; FIFO/SJF ignore it). ``arrival_tick`` is stamped by
    ``Engine.submit`` with the engine's tick counter at submission and is
    surfaced — together with per-request TTFT — in
    ``metrics()["requests"]``.
    """

    rid: int
    prompt: np.ndarray                  # (L,) int32
    max_new_tokens: int = 16
    priority: int = 0
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    arrival_tick: int = -1              # stamped at submit


@dataclasses.dataclass
class MigrationTicket:
    """Position-independent snapshot of one in-flight request — the unit
    of live migration (``Engine.export_request`` -> wire ->
    ``Engine.import_request``).

    ``state`` is the ``SequenceState.serialize`` buffer covering the first
    ``pos`` tokens of prompt ++ out_tokens (``None`` when nothing is
    resident — the target recomputes from scratch); it carries logical
    token order only, no physical block ids or slot indices, so source and
    target may disagree on pool geometry and mesh. Only the model and the
    ``cache_kind`` must match: a paged buffer cannot restore into a
    recurrent backend (``import_request`` rejects the mismatch loudly).
    """

    rid: int
    cache_kind: str
    priority: int
    max_new_tokens: int
    prompt: List[int]
    out_tokens: List[int]
    pos: int = 0                        # tokens the state buffer covers
    state: Optional[bytes] = None


@dataclasses.dataclass
class _Entry:
    """Scheduler state for one request (states: queued -> running ->
    finished, with running -> queued on preemption)."""

    req: Request
    handle: Optional[RequestHandle] = None
    pos: int = 0                        # tokens resident in the cache
    blocks: List[int] = dataclasses.field(default_factory=list)
    admit_seq: int = -1                 # first-admission stamp (victim order)
    arrival_seq: int = -1               # submit-order stamp (policy ties)
    submit_time: float = 0.0
    first_token_time: Optional[float] = None
    first_token_tick: Optional[int] = None
    preemptions: int = 0
    # prompt as python ints, converted once at submit (seq() runs every tick)
    prompt_tokens: List[int] = dataclasses.field(default_factory=list)
    # recurrent backend: host snapshot of the slot's state at eviction
    snapshot: Any = None
    # migrated-in state buffer, absorbed (then cleared) at admission
    inbound: Optional[bytes] = None

    def seq(self) -> List[int]:
        """prompt ++ generated — what must be resident before decoding."""
        return self.prompt_tokens + self.req.out_tokens


class Engine:
    """One serving engine over one mesh: pluggable scheduler, pluggable
    sequence-state backend, streaming outputs, fabric-routed steps.

    ``cache="paged"``: shared per-layer block pool (``num_blocks`` x
    ``block_size`` tokens), chunked prefill (``chunk`` tokens per tick)
    through the same compiled step as decode, block-budget-gated admission,
    preempt-and-requeue (recompute) on pool exhaustion. ``kernel`` selects
    the paged-attention path (docs/serving.md); on multi-device meshes the
    pallas kernel lowers through ``shard_map`` (kv heads over the tensor
    axis, request rows over the data axes, scheduler arrays replicated) —
    device count never forces the ``ref`` fallback, and MoE archs serve on
    any mesh (the step threads the real-token mask through every jam
    transport).

    ``cache="slots"``: one contiguous per-slot cache of ``max_len``,
    single-request prefill on admission, one decode tick per token — the
    legacy fixed-slot batcher, kept for MLA/hybrid archs and as the
    decode-bench baseline (exactness caveats: docs/serving.md).

    ``cache="recurrent"``: one constant-size state row per slot (SSM /
    xLSTM stacks only), chunked prefill through a masked-recurrence step,
    snapshot-eviction (never a recompute). Each row's recurrence is
    bitwise its unbatched result — the exactness the slots backend cannot
    give mixed-length batches.

    ``cache="auto"``: the model family's default backend
    (``registry.default_cache_backend``).

    ``scheduler`` is a policy name (``"fifo"``/``"priority"``/``"sjf"``) or
    any ``SchedulerPolicy`` object. FIFO reproduces the legacy servers
    bitwise, preemption paths included (tests/test_engine.py).
    """

    _ids = itertools.count()            # default engine_id allocator

    def __init__(self, cfg: ModelConfig, run: RunConfig, mesh: Mesh, *,
                 cache: str = "paged", slots: int, max_len: int,
                 scheduler="fifo", kernel: str = "auto",
                 num_blocks: Optional[int] = None, block_size: int = 16,
                 chunk: int = 8, eos_id: Optional[int] = None,
                 engine_id: Optional[str] = None, placement: str = "local"):
        assert not cfg.is_encoder, "encoder-only arch has no decode path"
        if placement not in ("local", "injected", "auto"):
            raise ValueError(f"placement must be 'local', 'injected', or "
                             f"'auto', got {placement!r}")
        if cache == "auto":
            from repro.configs import registry as registry_lib
            cache = registry_lib.default_cache_backend(cfg)
        if cache not in ("paged", "slots", "recurrent"):
            raise ValueError(
                f"cache must be 'paged', 'slots', or 'recurrent', "
                f"got {cache!r}")
        if kernel != "auto" and cache != "paged":
            raise ValueError(
                f"kernel={kernel!r} selects a paged-attention path; it has "
                f"no effect with cache={cache!r} — drop it or use "
                "cache='paged'")
        self.cfg, self.run, self.mesh = cfg, run, mesh
        self.cache_kind = cache
        self.engine_id = engine_id or f"engine-{next(Engine._ids)}"
        self.placement = placement
        self.slots, self.max_len, self.eos_id = slots, max_len, eos_id
        self.policy: SchedulerPolicy = resolve_policy(scheduler)
        self.params: Optional[PyTree] = None
        self.cache: Optional[PyTree] = None
        self.ticks = 0
        self.completed: List[Request] = []
        self.queue: List[_Entry] = []
        self.slot_entry: List[Optional[_Entry]] = [None] * slots
        self._finished: List[_Entry] = []
        self._submit_counter = 0
        self._admit_counter = 0
        self.admission_log: List[int] = []     # rids in first-admission order
        self.peak_active = 0
        self.preempt_count = 0
        self.migrations_in = 0
        self.migrations_out = 0
        self._placements: Dict[str, str] = {}
        self._pending_pump: List[_Entry] = []
        self._params_nbytes_memo: Optional[int] = None
        # chaos/recovery surface (repro.faults): a failed engine refuses
        # tick/submit/export until restart(); fault_hook fires between
        # placement resolution and step execution (the lease-race window);
        # lease_fallbacks counts auto→injected resolutions demoted to
        # local because the params lease expired inside that window
        self.failed_reason: Optional[str] = None
        self.fault_hook: Optional[Callable[[str], None]] = None
        self.lease_fallbacks = 0

        run_decode = dataclasses.replace(
            run, shape=dataclasses.replace(run.shape, kind="decode",
                                           seq_len=max_len,
                                           global_batch=slots))
        # graph tier (fabric.graph): active runs advanced one round per
        # tick, plus the lazily built multi-token verify step
        self._run_decode = run_decode
        self._kernel_req = kernel
        self._graphs: List[Any] = []
        self._graphs_done: List[Any] = []
        self.graph_invocations = 0
        self._jit_verify = None
        if cache == "paged":
            if num_blocks is None:
                raise ValueError("cache='paged' requires num_blocks=")
            self.block_size, self.chunk = block_size, chunk
            self.num_blocks = num_blocks
            self.max_blocks_per_seq = -(-max_len // block_size)
            if num_blocks < self.max_blocks_per_seq:
                raise ValueError(
                    f"num_blocks={num_blocks} cannot hold one "
                    f"max_len={max_len} request ({self.max_blocks_per_seq} "
                    f"blocks of {block_size})")
            self.bundle = make_paged_serve_step(
                cfg, run_decode, mesh, slots=slots, chunk=chunk,
                num_blocks=num_blocks, block_size=block_size,
                max_blocks_per_seq=self.max_blocks_per_seq, kernel=kernel)
            # resolved attention path ("pallas" | "ref") + per-step
            # live-token fraction: resident tokens / pool token capacity —
            # the occupancy knob the stash-resident kernel's bytes-read win
            # scales with (docs/serving.md)
            self.paged_kernel: str = self.bundle.meta["paged_kernel"]
            self._live_frac_last = 0.0
            self._live_frac_sum = 0.0
            self._live_frac_ticks = 0
            self.peak_blocks_used = 0
            self._step_name = "engine.paged_step"
        elif cache == "recurrent":
            self.chunk = chunk
            self.bundle = make_recurrent_serve_step(
                cfg, run_decode, mesh, slots=slots, chunk=chunk,
                max_len=max_len)
            self._step_name = "engine.recurrent_step"
        else:
            self.bundle = make_serve_step(cfg, run_decode, mesh,
                                          batch_override=slots)
            self._step_name = "engine.decode"
        self._jit_step = jax.jit(self.bundle.fn,
                                 in_shardings=self.bundle.in_shardings,
                                 out_shardings=self.bundle.out_shardings,
                                 donate_argnums=(1,))
        # the cache arg is donated, so pjit refuses to reshard it silently;
        # host-assembled caches (fresh init, prefill scatter) are re-placed
        # onto the step's declared shardings explicitly — a layout op, not
        # a numeric one (multi-device meshes fail without it)
        self._cache_shard = self.bundle.in_shardings[1]

        # --- sequence-state backend (the SequenceState protocol seam) ---
        self._make_state()
        if not self.state.supports_preemption:
            pv = getattr(type(self.policy), "pick_victim", None)
            if pv is not None and pv is not _PolicyBase.pick_victim:
                warnings.warn(
                    f"cache='slots' has no preemption path: "
                    f"{type(self.policy).__name__}.pick_victim will never "
                    "be consulted (admission order still applies); use "
                    "cache='paged' or 'recurrent' for preemption-aware "
                    "scheduling", UserWarning, stacklevel=2)
        _, self.params_shapes, _, _, self.pshard = sharding_ctx(
            cfg, run_decode, mesh)
        self._params_lease = f"{self._step_name}.params"
        self._register_fabric_steps()

    def _make_state(self) -> None:
        """(Re)build the sequence-state backend empty — shared by
        ``__init__`` and ``restart()`` (a restarted replica rejoins with a
        fresh pool, no surviving request state)."""
        template_fn = lambda: jax.jit(
            lambda: model_lib.init_cache(self.cfg, 1, self.max_len))()
        if self.cache_kind == "paged":
            self.state: SequenceState = PagedKVState(self.num_blocks,
                                                     self.block_size)
            self.pool = self.state.pool
        elif self.cache_kind == "recurrent":
            place = lambda t: jax.device_put(t, self._cache_shard)
            self.state = RecurrentState(self.slots, template_fn, place=place)
        else:
            self.state = SlotKVState(self.slots, template_fn)

    # ------------------------------------------------------------------
    # fabric registration / invocation — the one seam
    # ------------------------------------------------------------------

    @property
    def fabric(self):
        """The step bundle's Fabric — the invocation + telemetry surface."""
        return self.bundle.meta.get("fabric")

    @property
    def transport_decisions(self):
        """Auto-mode TransportEstimates recorded while tracing the step
        (delegates to the bundle fabric's decision log)."""
        if self.fabric is not None:
            return [est for _, est in self.fabric.decisions]
        return list(self.bundle.meta.get("transport_log", ()))

    def _register_fabric_steps(self) -> None:
        """Register the serve steps as collectives on the bundle fabric so
        every tick's invocation goes through ``fabric.call`` — the paper's
        one invocation surface. All three placements are real on the tick
        path: ``"local"`` runs against receiver-resident weights,
        ``"injected"`` acquires the step's rFaaS params lease every tick
        (the first acquire is the injection — a miss that ships the weight
        tree; later ticks hit warm), ``"auto"`` consults the cost model
        per tick (``_resolve_auto``). Every branch runs the same compiled
        step on the same mesh, so placement never changes the math — only
        where the weights are accounted as living. The resolved placement
        per step lands in ``metrics()["fabric"]["placements"]``."""
        fabric = self.fabric
        if fabric is None:              # pragma: no cover - bundles always
            return                      # carry a fabric; kept as a guard
        lease_name = self._params_lease

        def invoke_step(payload, state, placement):
            placement = self._guarded_placement(
                self._step_name, self._tick_payload_bytes(payload[1:]),
                state, placement)
            if placement == "injected":
                fabric.lease(lease_name, jax.tree.leaves(state))
            self._placements[self._step_name] = placement
            return self._jit_step(state, *payload)

        fabric.register_collective(self._step_name, invoke_step,
                                   placements=("local", "injected", "auto"))
        self._placements[self._step_name] = self.placement
        if self.cache_kind == "slots":
            def invoke_prefill(payload, state, placement):
                placement = self._guarded_placement(
                    "engine.prefill", self._tick_payload_bytes((payload,)),
                    state, placement)
                if placement == "injected":
                    fabric.lease(lease_name, jax.tree.leaves(state))
                self._placements["engine.prefill"] = placement
                one_cache = model_lib.init_cache(self.cfg, 1, self.max_len)
                return model_lib.forward(self.cfg, state, payload,
                                         cache=one_cache)

            fabric.register_collective(
                "engine.prefill", invoke_prefill,
                placements=("local", "injected", "auto"))
            self._placements["engine.prefill"] = self.placement

    def _step_call(self, *args):
        """One tick's compiled-step invocation, routed through the fabric
        at this engine's configured placement."""
        fabric = self.fabric
        if fabric is None:              # pragma: no cover - guard only
            return self._jit_step(self.params, *args)
        return fabric.call(self._step_name, args, state=self.params,
                           placement=self.placement)

    def _session_step_call(self, *args, placement: Optional[str] = None):
        """A graph session's step invocation — same fabric-registered
        step as ``tick``, but at the session's own placement."""
        self._check_alive("session step")
        return self.fabric.call(self._step_name, args, state=self.params,
                                placement=placement or self.placement)

    def ensure_verify_step(self) -> None:
        """Build + register the multi-token verify step lazily (paged
        only): the same serve step compiled with ``emit="all"`` — greedy
        token at *every* fed position instead of the last — which is what
        a speculation round reads to accept/reject k candidates in one
        invocation. Same geometry, same kernel, same cache layout; it
        shares the decode step's params lease and placement guard, and
        shows up in ``metrics()`` as ``engine.paged_verify``."""
        if self.cache_kind != "paged":
            raise ValueError(
                f"the verify step rides the paged chunked-prefill shape; "
                f"engine {self.engine_id} has cache={self.cache_kind!r}")
        if self._jit_verify is not None:
            return
        bundle = make_paged_serve_step(
            self.cfg, self._run_decode, self.mesh, slots=self.slots,
            chunk=self.chunk, num_blocks=self.num_blocks,
            block_size=self.block_size,
            max_blocks_per_seq=self.max_blocks_per_seq,
            kernel=self._kernel_req, emit="all")
        self._jit_verify = jax.jit(bundle.fn,
                                   in_shardings=bundle.in_shardings,
                                   out_shardings=bundle.out_shardings,
                                   donate_argnums=(1,))
        fabric = self.fabric
        if fabric is None:              # pragma: no cover - guard only
            return
        lease_name = self._params_lease

        def invoke_verify(payload, state, placement):
            placement = self._guarded_placement(
                "engine.paged_verify",
                self._tick_payload_bytes(payload[1:]), state, placement)
            if placement == "injected":
                fabric.lease(lease_name, jax.tree.leaves(state))
            self._placements["engine.paged_verify"] = placement
            return self._jit_verify(state, *payload)

        fabric.register_collective("engine.paged_verify", invoke_verify,
                                   placements=("local", "injected", "auto"))
        self._placements["engine.paged_verify"] = self.placement

    def _verify_call(self, *args, placement: Optional[str] = None):
        """One verify-step invocation through the fabric (lazily building
        the step on first use)."""
        self._check_alive("verify step")
        self.ensure_verify_step()
        if self.fabric is None:         # pragma: no cover - guard only
            return self._jit_verify(self.params, *args)
        return self.fabric.call("engine.paged_verify", args,
                                state=self.params,
                                placement=placement or self.placement)

    # -- placement resolution (the cost-model side of placement="auto") ----

    def _params_nbytes(self) -> int:
        if self._params_nbytes_memo is None and self.params is not None:
            self._params_nbytes_memo = sum(
                int(leaf.size) * leaf.dtype.itemsize
                for leaf in jax.tree.leaves(self.params)
                if hasattr(leaf, "dtype"))
        return self._params_nbytes_memo or 0

    @staticmethod
    def _tick_payload_bytes(payload) -> int:
        """Wire bytes of one tick's scheduler arrays (tokens / tables /
        starts / n_valid) — what placement='local' ships to wherever the
        weights live. The resident cache is excluded: sequence state stays
        put under either placement (migration, not placement, moves it)."""
        return sum(int(a.size) * a.dtype.itemsize
                   for a in jax.tree.leaves(payload)
                   if hasattr(a, "dtype"))

    def _lease_warm(self, state) -> bool:
        """True when a live params lease holds exactly these arrays (the
        ``is``-keyed hit condition of ``fabric.leases``)."""
        lease = self.fabric.leases.get(self._params_lease)
        leaves = jax.tree.leaves(state)
        return bool(lease is not None and lease.live
                    and len(lease.key) == len(leaves)
                    and all(a is b for a, b in zip(lease.key, leaves)))

    def _resolve_auto(self, name: str, payload_bytes: int, state) -> str:
        """Resolve placement='auto' for one tick: injected while the
        params lease is warm (the weights already live with the executor —
        reuse ships nothing), local while it is cold (a first injection
        would ship the whole weight tree for one tick's worth of payload).
        ``inject_params`` pre-warms the lease, so router-managed replicas
        resolve injected from their first tick. The estimate is recorded
        on the fabric's decision log either way."""
        warm = self._lease_warm(state)
        injected_bytes = 0 if warm else self._params_nbytes()
        est = TransportEstimate(
            local_bytes=payload_bytes, injected_bytes=injected_bytes,
            common_bytes=0,
            chosen="injected" if injected_bytes <= payload_bytes else "local",
            n_tokens_per_tp_rank=0, capacity=0)
        self.fabric.record_decision(name, est)
        return est.chosen

    def _guarded_placement(self, name: str, payload_bytes: int, state,
                           placement: str) -> str:
        """Resolve ``"auto"`` and close the lease-expiry race: the params
        lease can expire (TTL, eviction, an injected storm) *between*
        placement resolution and step execution — ``fault_hook`` fires in
        exactly that window. An auto resolution of ``injected`` was
        premised on warm reuse shipping zero bytes, so if the lease went
        cold underneath it the call falls back to ``local`` (counted in
        ``lease_fallbacks``) instead of silently re-shipping the whole
        weight tree — or erroring. An *explicit* ``placement="injected"``
        is untouched: re-acquiring on a cold lease IS the injection."""
        requested = placement
        if placement == "auto":
            placement = self._resolve_auto(name, payload_bytes, state)
        if self.fault_hook is not None:
            self.fault_hook(name)
        if (requested == "auto" and placement == "injected"
                and not self._lease_warm(state)):
            self.lease_fallbacks += 1
            placement = "local"
        return placement

    # ------------------------------------------------------------------
    # lifecycle
    # ------------------------------------------------------------------

    def load_params(self, params: Optional[PyTree] = None) -> None:
        """Install model weights (init randomly when none given)."""
        if params is None:
            init = jax.jit(lambda k: model_lib.init_params(self.cfg, k)[0],
                           out_shardings=self.pshard)
            params = init(jax.random.PRNGKey(self.run.seed))
        self.params = params
        self._params_nbytes_memo = None
        self.cache = self._fresh_cache()

    def inject_params(self, params: Optional[PyTree] = None) -> None:
        """Install weights *and* warm the step's params lease — the
        executor side of ``placement="injected"``/``"auto"``: a router
        shipping one shared weight tree to N replicas calls this instead
        of ``load_params``, after which ``placement="auto"`` resolves to
        injected (warm reuse) from the replica's first tick and the
        injection itself is visible as the lease's one miss."""
        self.load_params(params)
        if self.fabric is not None:
            self.fabric.lease(self._params_lease,
                              jax.tree.leaves(self.params))

    # -- failure lifecycle (the replica side of cluster failover) ---------

    @property
    def alive(self) -> bool:
        return self.failed_reason is None

    def fail(self, reason: str = "injected failure") -> None:
        """Put the engine into the failed state: every subsequent tick /
        submit / export / import / snapshot raises ``EngineFailedError``
        until ``restart()``. Host-side bookkeeping (metrics, completed
        requests) stays readable — a dead process's logs survive it."""
        self.failed_reason = reason

    def restart(self) -> None:
        """Simulate a process restart: clear the failure flag and abandon
        ALL request state — queue, slots, pool blocks, stream handles —
        so the replica rejoins empty (a real restarted process holds no
        sequence state; the router has already recovered its requests
        elsewhere). Params and compiled steps survive: they are
        process-image, not request state."""
        self.failed_reason = None
        for entry in self._entries_everywhere():
            entry.handle = None
        self.queue.clear()
        self.slot_entry = [None] * self.slots
        self._pending_pump.clear()
        self._graphs.clear()            # sessions die with the pool
        self._make_state()
        if self.params is not None:
            self.cache = self._fresh_cache()

    def _check_alive(self, what: str) -> None:
        if self.failed_reason is not None:
            raise EngineFailedError(
                self.engine_id, f"{self.failed_reason} (refusing {what})")

    def _fresh_cache(self) -> PyTree:
        if self.cache_kind == "paged":
            fresh = jax.jit(lambda: model_lib.init_paged_cache(
                self.cfg, self.num_blocks, self.block_size))()
        else:
            fresh = jax.jit(lambda: model_lib.init_cache(
                self.cfg, self.slots, self.max_len))()
        return jax.device_put(fresh, self._cache_shard)

    def pending(self) -> bool:
        """True while any request is queued or occupying a slot, or any
        graph run is still looping."""
        return bool(self.queue
                    or any(e is not None for e in self.slot_entry)
                    or any(not run.done for run in self._graphs))

    def run_until_drained(self, max_ticks: int = 10_000) -> List[Request]:
        """Serve until queue + slots drain; returns completed requests.
        (Streaming clients can instead pull ``handle.tokens()``.)"""
        while self.pending() and self.ticks < max_ticks:
            self.tick()
        return self.completed

    # ------------------------------------------------------------------
    # request plumbing
    # ------------------------------------------------------------------

    def submit(self, req: Request) -> RequestHandle:
        """Queue a request; returns its streaming ``RequestHandle``."""
        self._check_alive("submit")
        # reject up front what could never finish: past this check a
        # request's sequence always fits the backend's capacity model
        # (for paged: max_blocks_per_seq blocks, so the block table row
        # cannot overflow and a lone request never starves)
        msg = self.state.validate(len(req.prompt), req.max_new_tokens,
                                  self.max_len)
        if msg:
            raise ValueError(f"request {req.rid}: {msg}")
        req.arrival_tick = self.ticks
        entry = _Entry(req=req, submit_time=time.perf_counter(),
                       arrival_seq=self._submit_counter,
                       prompt_tokens=[int(t) for t in req.prompt])
        self._submit_counter += 1
        entry.handle = RequestHandle(self, req)
        self.queue.append(entry)
        return entry.handle

    def submit_graph(self, spec, inputs, *, loop_until=None,
                     max_rounds: int = 256, resolve=None,
                     on_node_error=None):
        """Queue a ``fabric.graph`` run; returns its streaming
        ``GraphHandle``. The scheduler admits the run's *node
        invocations*: each ``tick`` advances every active graph one
        round (all nodes once, topo order) alongside the request rows,
        node outputs land as warm leases on this engine's fabric
        (``graph/<gid>/<node>``), and ``handle.tokens()`` drives
        ``tick()`` exactly like ``RequestHandle.tokens()`` does. Graphs
        that loop (``loop_until``) keep their round cadence: one
        speculation round per tick for the draft/verify graph."""
        self._check_alive("submit_graph")
        from repro.fabric.graph.executor import GraphRun
        run = GraphRun(spec, inputs, fabric=self.fabric,
                       loop_until=loop_until, max_rounds=max_rounds,
                       resolve=resolve, on_node_error=on_node_error)
        self._graphs.append(run)
        return run.handle._bind(self)

    def _tick_graphs(self) -> int:
        """Advance every active graph run one round; returns the number
        of node invocations fired."""
        fired = 0
        for run in list(self._graphs):
            if not run.done:
                fired += run.advance()
            if run.done:
                self._graphs.remove(run)
                self._graphs_done.append(run)
        self.graph_invocations += fired
        return fired

    def _sched_state(self, block_budget: Optional[int]) -> SchedulerState:
        return SchedulerState(
            tick=self.ticks,
            free_slots=sum(e is None for e in self.slot_entry),
            block_budget=block_budget,
            blocks_needed=self.state.units_needed,
            capacity=self.state.capacity())

    def _stamp_admitted(self, entry: _Entry) -> None:
        if entry.admit_seq < 0:
            entry.admit_seq = self._admit_counter
            self._admit_counter += 1
            self.admission_log.append(entry.req.rid)

    def _emit(self, entry: _Entry, tok: int) -> None:
        """Append one generated token + TTFT stamps; streaming delivery is
        deferred to ``_flush_streams`` at the end of the tick so a raising
        client callback can never abort the engine's own bookkeeping
        mid-loop (which would silently drop co-scheduled tokens)."""
        entry.req.out_tokens.append(tok)
        if len(entry.req.out_tokens) == 1:
            entry.first_token_time = time.perf_counter()
            entry.first_token_tick = self.ticks
        if entry.handle is not None:
            self._pending_pump.append(entry)

    def _flush_streams(self) -> None:
        """Deliver this tick's tokens to stream callbacks. Runs after all
        token appends/completions; a raising callback propagates to the
        tick() caller but leaves the engine consistent — undelivered
        entries stay queued and flush on the next tick."""
        while self._pending_pump:
            entry = self._pending_pump.pop(0)
            if entry.handle is not None:
                entry.handle._pump()

    def _complete(self, slot: int, entry: _Entry) -> None:
        entry.req.done = True
        self.state.release(entry)
        self.completed.append(entry.req)
        self._finished.append(entry)
        self.slot_entry[slot] = None

    def _entries_everywhere(self) -> List[_Entry]:
        out = list(self.queue) + [e for e in self.slot_entry if e is not None]
        out.extend(self._finished)
        return out

    # ------------------------------------------------------------------
    # tick — one admit/step/complete round, backend-dispatched
    # ------------------------------------------------------------------

    def tick(self) -> int:
        """Admit + advance every active request one step, then every
        active graph run one round. Returns rows advanced plus node
        invocations fired."""
        self._check_alive("tick")
        if self.cache_kind == "slots":
            advanced = self._tick_slots()
        else:
            advanced = self._tick_chunked()
        if self._graphs:
            advanced += self._tick_graphs()
        return advanced

    # -- slots (fixed-slot contiguous cache) backend ----------------------

    def _admit_slots(self) -> None:
        for slot in range(self.slots):
            if self.slot_entry[slot] is not None or not self.queue:
                continue
            idx = self.policy.admit(self.queue, self._sched_state(None))
            if idx is None:
                return
            entry = self.queue.pop(idx)
            self._stamp_admitted(entry)
            if entry.inbound is not None:
                # migrated-in: the serialized row replaces the prefill
                # forward (its tokens are already absorbed); the next
                # decode tick feeds out_tokens[-1] like any resident row
                self.slot_entry[slot] = entry
                self._restore_inbound(entry, slot)
            else:
                self._prefill_slot(slot, entry)

    def _prefill_slot(self, slot: int, entry: _Entry) -> None:
        """Run the prompt through the model, writing this slot's cache rows.

        Single-slot prefill through the fabric-registered ``engine.prefill``
        step: a (1, L) forward with a fresh length-``max_len`` cache, then
        scatter the slot row into the live batched cache.
        """
        req = entry.req
        # Recovery recompute (failover rebuilt this request from prompt +
        # already-delivered tokens, no state bytes): re-run everything
        # known except the newest token — the next decode tick feeds it
        # back exactly like any resident row — and emit nothing, because
        # every known token was already delivered upstream. Fresh requests
        # (no out_tokens) keep the original prompt-only + argmax path.
        known = entry.seq()
        tokens = known[:-1] if req.out_tokens else known
        prompt = jnp.asarray(tokens, jnp.int32)[None, :]
        fabric = self.fabric
        if fabric is None:              # pragma: no cover - guard only
            one_cache = model_lib.init_cache(self.cfg, 1, self.max_len)
            logits, filled, _ = model_lib.forward(
                self.cfg, self.params, prompt, cache=one_cache)
        else:
            logits, filled, _ = fabric.call("engine.prefill", prompt,
                                            state=self.params,
                                            placement="local")
        if not req.out_tokens:
            self._emit(entry, int(jnp.argmax(logits[0, -1, :])))

        def scatter(live, one):
            # Cache leaves may carry a leading layer-stack dim
            # ((repeats, B, ...) for scanned groups), so the batch axis is
            # located structurally: the first axis where the live leaf has
            # ``slots`` extent, the one-row prefill leaf has extent 1, and
            # every leading dim matches. (Matching on shape[:1] mistook the
            # layer-stack dim for batch: slots=1 silently dropped the whole
            # prefill and slots==repeats scattered layers as slots.)
            if getattr(live, "ndim", 0) == 0:
                return live
            for ax in range(live.ndim):
                if (live.shape[ax] == self.slots and one.shape[ax] == 1
                        and live.shape[:ax] == one.shape[:ax]):
                    idx = (slice(None),) * ax + (slot,)
                    return live.at[idx].set(jnp.take(one, 0, axis=ax))
            return live

        # lengths differ per slot; keep the max (cache length is per-batch
        # scalar — decode masks by absolute position so overshoot is safe)
        new_groups = jax.tree.map(scatter, self.cache["groups"],
                                  filled["groups"])
        self.cache = jax.device_put(
            {"length": jnp.maximum(self.cache["length"], filled["length"]),
             "groups": new_groups}, self._cache_shard)
        self.slot_entry[slot] = entry

    def _tick_slots(self) -> int:
        self._admit_slots()
        active = [i for i, e in enumerate(self.slot_entry) if e is not None]
        if not active:
            self._flush_streams()       # leftovers from a raising flush
            return 0
        self.peak_active = max(self.peak_active, len(active))
        tokens = np.zeros((self.slots, 1), np.int32)
        for i, e in enumerate(self.slot_entry):
            if e is not None:
                tokens[i, 0] = e.req.out_tokens[-1]
        args = [self.cache, jnp.asarray(tokens)]
        if self.cfg.attention is not None and self.cfg.attention.mrope:
            pos = np.broadcast_to(
                np.asarray(self.cache["length"])[None, None],
                (3, self.slots, 1)).astype(np.int32)
            args.append(jnp.asarray(pos))
        next_tok, self.cache = self._step_call(*args)
        next_np = np.asarray(next_tok)
        for i in active:
            e = self.slot_entry[i]
            tok = int(next_np[i, 0])
            self._emit(e, tok)
            if (len(e.req.out_tokens) >= e.req.max_new_tokens
                    or (self.eos_id is not None and tok == self.eos_id)):
                self._complete(i, e)
        self.ticks += 1
        self._flush_streams()
        return len(active)

    # -- chunked (paged / recurrent) backends -----------------------------

    def _blocks_for(self, tokens: int) -> int:
        return -(-tokens // self.block_size)

    def _admit_chunked(self) -> None:
        """Policy-gated admission: the policy picks the next queued entry;
        it admits only when a slot is free AND the backend can hold its
        whole resident prefix plus one decode token. ``budget`` tracks the
        capacity units already promised to entries admitted in this same
        call — their allocation happens later in tick phase A, so reading
        ``capacity().free_units`` alone would over-commit the pool and
        trigger spurious preemptions of just-admitted requests. Backends
        whose capacity is not consumable (``free_units`` None) gate on
        free slots alone."""
        budget = self.state.capacity().free_units
        while self.queue:
            free_slots = [i for i, e in enumerate(self.slot_entry)
                          if e is None]
            if not free_slots:
                return
            state = self._sched_state(budget)
            idx = self.policy.admit(self.queue, state)
            if idx is None:
                return                  # policy head blocked => wait
            entry = self.queue.pop(idx)
            if budget is not None:
                # debit what the policy *reserved* (its budget() — >= the
                # exact need, e.g. headroom-reserving policies), never less
                # than the real need, so the round ledger cannot over-commit
                budget -= max(self.policy.budget(entry, state),
                              self.state.units_needed(entry))
            self._stamp_admitted(entry)
            slot = free_slots[0]
            self.slot_entry[slot] = entry
            self.cache = self.state.init(entry, self.cache, slot)
            if entry.inbound is not None:
                self._restore_inbound(entry, slot)

    def _restore_inbound(self, entry: _Entry, slot: int) -> None:
        """Absorb a migrated-in request's serialized state into ``slot``
        (the admission side of ``import_request``). Paged entries first
        re-acquire blocks covering the resident prefix — growth may
        preempt a victim, exactly as a native request's growth would; the
        restored rows then land in this pool's own blocks. After this the
        entry is indistinguishable from one that prefilled here: chunked
        backends resume at ``entry.pos``, slots decode from
        ``out_tokens[-1]``."""
        if self.cache_kind == "paged":
            self._ensure_capacity(entry, max(entry.pos, 1))
        self.cache = jax.device_put(
            self.state.restore(entry, self.cache, slot, entry.inbound),
            self._cache_shard)
        entry.inbound = None

    def _preempt(self, victim: _Entry) -> None:
        """Evict the victim through the backend and requeue it in admission
        order: before every never-admitted entry and every
        previously-preempted entry with a younger admit stamp. (Plain
        front-insertion breaks FIFO when two preemptions land out of stamp
        order — e.g. the youngest running entry grows and evicts a
        middle-aged one, then an older entry evicts the youngest.)
        Generated tokens are kept. What eviction *costs* is the backend's
        call: paged releases blocks and resets ``pos`` (re-admission
        re-prefills — recompute), recurrent snapshots the slot's state and
        keeps ``pos`` (re-admission resumes — never a recompute), slots
        raises (no preemption path). Reordering policies re-decide at the
        next admission anyway, so the stamp-ordered insert is
        policy-neutral."""
        slot = self.slot_entry.index(victim)
        self.cache = self.state.evict(victim, self.cache, slot)
        victim.preemptions += 1
        self.preempt_count += 1
        self.slot_entry[slot] = None
        at = next((i for i, e in enumerate(self.queue)
                   if e.admit_seq < 0 or e.admit_seq > victim.admit_seq),
                  len(self.queue))
        self.queue.insert(at, victim)

    def _ensure_capacity(self, entry: _Entry, upto_tokens: int) -> None:
        """Grow the entry's state to cover ``upto_tokens``, preempting the
        policy's victim among the other running requests whenever the
        backend reports exhaustion."""
        while not self.state.grow(entry, upto_tokens):
            running = [e for e in self.slot_entry
                       if e is not None and e is not entry]
            victim = self.policy.pick_victim(running, self._sched_state(0))
            if victim is None:
                # unreachable given the num_blocks >= max_blocks_per_seq
                # init check: a lone request always fits
                raise RuntimeError("block pool exhausted by a single request")
            self._preempt(victim)

    def _tick_chunked(self) -> int:
        self._admit_chunked()
        paged = self.cache_kind == "paged"

        # phase A: chunk sizing + capacity growth (may preempt victims,
        # including entries already scheduled earlier in this loop).
        # seq is materialized once per entry per tick — it is O(seq_len).
        sched: List[Tuple[int, _Entry, int, List[int]]] = []
        for slot in range(self.slots):
            entry = self.slot_entry[slot]
            if entry is None:
                continue
            seq = entry.seq()
            n = min(self.chunk, len(seq) - entry.pos)
            self._ensure_capacity(entry, entry.pos + n)
            sched.append((slot, entry, n, seq))
        sched = [item for item in sched if self.slot_entry[item[0]] is item[1]]
        # the tick counts even when nothing is schedulable, so
        # run_until_drained's max_ticks stays a hard bound (a queue head
        # that can never admit must not spin forever)
        self.ticks += 1
        if not sched:
            self._flush_streams()       # leftovers from a raising flush
            return 0
        self.peak_active = max(self.peak_active, len(sched))
        if paged:
            self.peak_blocks_used = max(self.peak_blocks_used,
                                        self.pool.used_blocks)
            # tokens resident after this step's writes / pool token capacity
            live = sum(entry.pos + n for _, entry, n, _ in sched)
            self._live_frac_last = live / (self.num_blocks * self.block_size)
            self._live_frac_sum += self._live_frac_last
            self._live_frac_ticks += 1

        # phase B: build the fixed-shape step inputs
        tokens = np.zeros((self.slots, self.chunk), np.int32)
        starts = np.zeros((self.slots,), np.int32)
        n_valid = np.zeros((self.slots,), np.int32)
        if paged:
            tables = np.full((self.slots, self.max_blocks_per_seq), -1,
                             np.int32)
        for slot, entry, n, seq in sched:
            tokens[slot, :n] = seq[entry.pos:entry.pos + n]
            if paged:
                tables[slot, :len(entry.blocks)] = entry.blocks
            starts[slot] = entry.pos
            n_valid[slot] = n

        args = [self.cache, jnp.asarray(tokens)]
        if paged:
            args.append(jnp.asarray(tables))
        args.extend([jnp.asarray(starts), jnp.asarray(n_valid)])
        next_tok, self.cache = self._step_call(*args)
        next_np = np.asarray(next_tok)

        for slot, entry, n, seq in sched:
            known = len(seq)
            entry.pos += n
            self.state.append(entry, n)
            if entry.pos < known:
                continue                 # mid-prefill: output discarded
            tok = int(next_np[slot])
            self._emit(entry, tok)
            if (len(entry.req.out_tokens) >= entry.req.max_new_tokens
                    or (self.eos_id is not None and tok == self.eos_id)):
                self._complete(slot, entry)

        self._flush_streams()
        return len(sched)

    def preempt(self, rid: int) -> None:
        """Evict a running request by id through the backend's preemption
        path and requeue it (admission-ordered). Paged requeues recompute
        the prefix; recurrent resumes from its state snapshot; slots
        raises — it has no preemption path."""
        for entry in self.slot_entry:
            if entry is not None and entry.req.rid == rid:
                self._preempt(entry)
                return
        raise KeyError(f"request {rid} is not running in any slot")

    # ------------------------------------------------------------------
    # live migration — export/import of in-flight entries (ROADMAP item 3)
    # ------------------------------------------------------------------

    def export_request(self, rid: int) -> MigrationTicket:
        """Detach request ``rid`` — queued or running — into a
        position-independent ``MigrationTicket`` and release everything it
        held here (slot, blocks, snapshot, stream handle). Called between
        ticks by a router; the ticket restores on any engine with the same
        model and ``cache_kind`` via ``import_request``, resuming with
        greedy output bitwise identical to never having moved. Raises
        ``KeyError`` for unknown or finished rids (a finished request has
        nothing left to move)."""
        self._check_alive("export_request")
        for slot in range(self.slots):
            entry = self.slot_entry[slot]
            if entry is not None and entry.req.rid == rid:
                return self._export_entry(entry, slot)
        for i, entry in enumerate(self.queue):
            if entry.req.rid == rid:
                self.queue.pop(i)
                return self._export_entry(entry, None)
        raise KeyError(
            f"request {rid} is not queued or running on {self.engine_id} "
            f"(finished requests cannot migrate)")

    def _export_entry(self, entry: _Entry,
                      slot: Optional[int]) -> MigrationTicket:
        req = entry.req
        buf: Optional[bytes] = None
        pos = 0
        if slot is not None:
            if self.cache_kind == "slots":
                # resident: whole prompt + every generated token except
                # the newest (it has not been fed back through the step)
                buf = self.state.serialize(entry, self.cache, slot)
                pos = (len(entry.prompt_tokens)
                       + max(0, len(req.out_tokens) - 1))
            elif entry.pos > 0:
                buf = self.state.serialize(entry, self.cache, slot)
                pos = entry.pos
            self.slot_entry[slot] = None
        elif entry.inbound is not None:
            # migrated in but re-exported before admission absorbed the
            # buffer: forward it verbatim (dropping it would silently
            # demote a warm handoff to a from-scratch recompute)
            buf = entry.inbound
            pos = entry.pos
        elif self.cache_kind == "recurrent" and entry.snapshot is not None:
            # preempted-and-requeued: the host snapshot IS the state
            buf = state_to_bytes(entry.snapshot)
            pos = entry.pos
        self.state.release(entry)
        ticket = self._ticket_for(entry, buf, pos)
        # detach the local stream: the source-side handle must not see
        # tokens the target produces (the router rebinds its own handle)
        entry.handle = None
        self._pending_pump = [e for e in self._pending_pump if e is not entry]
        self.migrations_out += 1
        return ticket

    def _ticket_for(self, entry: _Entry, buf: Optional[bytes],
                    pos: int) -> MigrationTicket:
        req = entry.req
        return MigrationTicket(
            rid=req.rid, cache_kind=self.cache_kind, priority=req.priority,
            max_new_tokens=req.max_new_tokens,
            prompt=list(entry.prompt_tokens),
            out_tokens=list(req.out_tokens), pos=pos, state=buf)

    def snapshot_request(self, rid: int) -> MigrationTicket:
        """Non-destructive twin of ``export_request``: serialize ``rid``'s
        sequence state into a ``MigrationTicket`` *without* releasing
        anything — the request keeps running here, slot and blocks intact.
        A router takes these periodically (its snapshot cadence) so that
        when this replica dies, the request restores on a peer from the
        last snapshot — regenerating only the tokens emitted since — in
        place of a full from-scratch recompute. Raises ``KeyError`` for
        unknown or finished rids."""
        self._check_alive("snapshot_request")
        for slot in range(self.slots):
            entry = self.slot_entry[slot]
            if entry is None or entry.req.rid != rid:
                continue
            buf: Optional[bytes] = None
            pos = 0
            if self.cache_kind == "slots":
                # same coverage rule as export: everything but the newest
                # token (not yet fed back through the step)
                buf = self.state.serialize(entry, self.cache, slot)
                pos = (len(entry.prompt_tokens)
                       + max(0, len(entry.req.out_tokens) - 1))
            elif entry.pos > 0:
                buf = self.state.serialize(entry, self.cache, slot)
                pos = entry.pos
            return self._ticket_for(entry, buf, pos)
        for entry in self.queue:
            if entry.req.rid != rid:
                continue
            if entry.inbound is not None:
                return self._ticket_for(entry, entry.inbound, entry.pos)
            if (self.cache_kind == "recurrent"
                    and entry.snapshot is not None):
                return self._ticket_for(entry, state_to_bytes(entry.snapshot),
                                        entry.pos)
            return self._ticket_for(entry, None, 0)
        raise KeyError(
            f"request {rid} is not queued or running on {self.engine_id} "
            f"(finished requests have no state to snapshot)")

    def import_request(self, ticket: MigrationTicket) -> RequestHandle:
        """Admit a migrated request. The rebuilt entry enters the queue
        like a fresh submit (policies see its original priority); its
        serialized state — when the ticket carries one — is absorbed at
        admission by ``_restore_inbound`` instead of a prefill, so
        decoding resumes at token ``pos`` with no recompute (paged resumes
        even mid-chunked-prefill: ``pos`` is a chunk boundary and the
        chunk policy is deterministic). Tickets from a different backend
        are rejected: the state bytes are only meaningful to their own
        ``cache_kind``."""
        self._check_alive("import_request")
        if ticket.cache_kind != self.cache_kind:
            raise ValueError(
                f"cannot import a cache_kind={ticket.cache_kind!r} ticket "
                f"into {self.engine_id} (cache_kind={self.cache_kind!r}): "
                f"sequence-state bytes do not convert across backends")
        prompt = np.asarray(ticket.prompt, np.int32)
        msg = self.state.validate(len(ticket.prompt), ticket.max_new_tokens,
                                  self.max_len)
        if msg:
            raise ValueError(f"request {ticket.rid}: {msg}")
        req = Request(rid=ticket.rid, prompt=prompt,
                      max_new_tokens=ticket.max_new_tokens,
                      priority=ticket.priority,
                      out_tokens=list(ticket.out_tokens))
        req.arrival_tick = self.ticks
        entry = _Entry(req=req, submit_time=time.perf_counter(),
                       arrival_seq=self._submit_counter,
                       prompt_tokens=list(ticket.prompt))
        self._submit_counter += 1
        if ticket.state is not None:
            entry.inbound = ticket.state
            entry.pos = ticket.pos
        entry.handle = RequestHandle(self, req)
        self.queue.append(entry)
        self.migrations_in += 1
        return entry.handle

    # ------------------------------------------------------------------
    # metrics — one unified schema for both backends
    # ------------------------------------------------------------------

    def _request_records(self) -> List[Dict[str, Any]]:
        """Per-request metrics (submit order): priority/arrival/TTFT —
        previously reconstructible only from server internals."""
        recs = []
        for e in sorted(self._entries_everywhere(),
                        key=lambda e: e.arrival_seq):
            r = e.req
            recs.append({
                "rid": r.rid,
                "priority": r.priority,
                "arrival_tick": r.arrival_tick,
                "admitted": e.admit_seq >= 0,
                "first_token_tick": e.first_token_tick,
                "ttft_s": (e.first_token_time - e.submit_time
                           if e.first_token_time is not None else None),
                "tokens": len(r.out_tokens),
                "preemptions": e.preemptions,
                "done": r.done,
            })
        return recs

    def _transport_metrics(self) -> Dict[str, Any]:
        """Transport telemetry block of ``metrics()`` — delegates to the
        bundle fabric (the ``fabric`` key carries its full ``metrics()``
        dict plus the resolved placement of every engine-registered step);
        the two legacy keys are kept for pre-Fabric consumers."""
        out: Dict[str, Any] = {
            "transport_decisions": [est.describe()
                                    for est in self.transport_decisions],
            "transport_telemetry": transport_lib.get_telemetry().summary(),
        }
        if self.fabric is not None:
            fm = self.fabric.metrics()
            fm["placements"] = dict(self._placements)
            fm["lease_fallbacks"] = self.lease_fallbacks
            out["fabric"] = fm
        return out

    def metrics(self) -> Dict[str, Any]:
        """Unified engine telemetry snapshot (JSON-friendly).

        One schema for both cache backends: scheduler progress, per-request
        records (``requests``), TTFT distribution, preemption counters, and
        the fabric/transport block; the paged backend adds its pool keys
        (same names the legacy paged server reported). docs/engine.md
        documents every key.
        """
        done = [e for e in self._entries_everywhere() if e.req.done]
        ttfts = sorted(e.first_token_time - e.submit_time
                       for e in done if e.first_token_time is not None)
        out: Dict[str, Any] = {
            "engine": {
                # engine_id first: the merge key multi-replica metric
                # consumers (cluster.metrics()) disambiguate replicas by
                "engine_id": self.engine_id,
                "cache": self.cache_kind,
                "scheduler": self.policy.name,
                "slots": self.slots,
                "max_len": self.max_len,
                "placement": self.placement,
                "failed_reason": self.failed_reason,
            },
            "ticks": self.ticks,
            "active_slots": sum(e is not None for e in self.slot_entry),
            "peak_active_slots": self.peak_active,
            "queued": len(self.queue),
            "completed": len(self.completed),
            "preemptions": self.preempt_count,
            "migrations": {"in": self.migrations_in,
                           "out": self.migrations_out},
            "ttft_s": ttfts,
            "requests": self._request_records(),
            **self._transport_metrics(),
        }
        if self._graphs or self._graphs_done:
            out["graphs"] = {
                "active": len(self._graphs),
                "completed": len(self._graphs_done),
                "node_invocations": self.graph_invocations,
                "runs": [run.metrics()
                         for run in self._graphs + self._graphs_done],
            }
        if self.cache_kind == "paged":
            out.update({
                "paged_kernel": self.paged_kernel,
                "live_token_fraction": self._live_frac_last,
                "live_token_fraction_mean": (
                    self._live_frac_sum / self._live_frac_ticks
                    if self._live_frac_ticks else 0.0),
                "num_blocks": self.num_blocks,
                "block_size": self.block_size,
                "chunk": self.chunk,
                "free_blocks": self.pool.free_blocks,
                "used_blocks": self.pool.used_blocks,
                "peak_used_blocks": self.peak_blocks_used,
                "occupancy": self.pool.used_blocks / max(1, self.num_blocks),
            })
        elif self.cache_kind == "recurrent":
            out.update({"chunk": self.chunk, **self.state.metrics()})
        return out
