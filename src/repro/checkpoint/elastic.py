"""Elastic restore: resume a checkpoint on a DIFFERENT mesh.

A checkpoint stores unsharded (global) arrays, so elasticity is a placement
problem, not a data problem: build the sharding rules for the *new* mesh,
resolve a fresh NamedSharding tree against the same logical axes, and
device_put each leaf. Shrinking the ``data`` axis after a host failure, or
growing it when capacity returns, both reduce to this (the paper's ried
re-installation on a changed set of processes).
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
from jax.sharding import Mesh

from repro.checkpoint.manager import latest_step, restore
from repro.configs.base import ModelConfig, RunConfig
from repro.runtime import mesh_util

PyTree = Any


def reshard_restore(ckpt_dir: str, cfg: ModelConfig, run: RunConfig,
                    new_mesh: Mesh, *, step: Optional[int] = None
                    ) -> Tuple[int, PyTree, PyTree]:
    """Restore (params, opt_state) onto ``new_mesh``.

    Returns (step, params, opt_state). Raises FileNotFoundError when no
    committed checkpoint exists.
    """
    from repro.runtime.steps import (abstract_opt_state, abstract_params,
                                     opt_shardings)

    step = latest_step(ckpt_dir) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no committed checkpoint in {ckpt_dir}")

    rules = mesh_util.make_rules(run.sharding, new_mesh)
    params_shapes, axes = abstract_params(cfg)
    pshard = mesh_util.param_shardings(axes, params_shapes, rules, new_mesh)
    oshard = opt_shardings(pshard, new_mesh)

    params = restore(ckpt_dir, step, {"params": params_shapes},
                     {"params": pshard})["params"]
    opt = restore(ckpt_dir, step, {"opt": abstract_opt_state(params_shapes)},
                  {"opt": oshard})["opt"]
    return step, params, opt
