"""Checkpointing: async save, retention, atomic commit, restore.

Design (the ried side of Two-Chains: resident state driven to a process):

  * A checkpoint is a directory ``step_<N>/`` holding one ``arrays.npz``
    (flattened pytree leaves keyed by path) + ``meta.json`` (treedef paths,
    step, config json, wall time). A ``COMMIT`` marker file makes the save
    atomic — restore ignores uncommitted directories, so a host failure
    mid-save never corrupts the latest checkpoint.
  * ``save`` is asynchronous: leaves are fetched to host (blocking only on
    device->host copy), then serialized on a background thread so the train
    loop resumes immediately — checkpoint I/O overlaps the next steps.
  * Retention keeps the newest ``keep`` committed checkpoints.
  * ``restore`` places leaves back onto the mesh with the provided shardings
    (``jax.device_put`` with NamedSharding — works across mesh shapes, which
    is what ``checkpoint.elastic`` builds on).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro import compat

PyTree = Any

_COMMIT = "COMMIT"


def _path_str(path) -> str:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
        elif hasattr(p, "name"):
            out.append(str(p.name))
        else:
            out.append(str(p))
    return "/".join(out)


def flatten_with_paths(tree: PyTree) -> Tuple[List[Tuple[str, Any]], Any]:
    leaves, treedef = compat.tree_flatten_with_path(tree)
    return [(_path_str(p), leaf) for p, leaf in leaves], treedef


def latest_step(ckpt_dir: str) -> Optional[int]:
    """Newest committed step in ``ckpt_dir`` (None if no valid checkpoint)."""
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and os.path.exists(
                os.path.join(ckpt_dir, name, _COMMIT)):
            try:
                steps.append(int(name.split("_", 1)[1]))
            except ValueError:
                continue
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, template: PyTree,
            shardings: Optional[PyTree] = None) -> PyTree:
    """Load ``step_<step>`` into the structure of ``template``.

    ``shardings``: optional NamedSharding tree — leaves are device_put with
    it (sharded placement; used by elastic restore onto a different mesh).
    """
    d = os.path.join(ckpt_dir, f"step_{step}")
    if not os.path.exists(os.path.join(d, _COMMIT)):
        raise FileNotFoundError(f"no committed checkpoint at {d}")
    with np.load(os.path.join(d, "arrays.npz")) as z:
        data = {k: z[k] for k in z.files}
    pairs, treedef = flatten_with_paths(template)
    shard_leaves = (jax.tree.leaves(shardings) if shardings is not None
                    else [None] * len(pairs))
    out = []
    for (path, leaf), sh in zip(pairs, shard_leaves):
        arr = data[path]
        if hasattr(leaf, "dtype"):
            arr = arr.astype(leaf.dtype)
        out.append(jax.device_put(arr, sh) if sh is not None else arr)
    return jax.tree.unflatten(treedef, out)


class CheckpointManager:
    """Async checkpoint writer with retention."""

    def __init__(self, ckpt_dir: str, *, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        os.makedirs(ckpt_dir, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # -- save ------------------------------------------------------------------
    def save(self, step: int, tree: PyTree, *, meta: Optional[Dict] = None,
             blocking: bool = False) -> None:
        """Snapshot ``tree`` at ``step``. Device->host copy happens here;
        serialization happens on a background thread unless ``blocking``."""
        self.wait()  # one in-flight save at a time
        pairs, _ = flatten_with_paths(tree)
        host = [(p, np.asarray(leaf)) for p, leaf in pairs]
        info = dict(meta or {}, step=step, time=time.time())

        def write():
            try:
                final = os.path.join(self.ckpt_dir, f"step_{step}")
                tmp = final + ".tmp"
                shutil.rmtree(tmp, ignore_errors=True)
                shutil.rmtree(final, ignore_errors=True)
                os.makedirs(tmp)
                np.savez(os.path.join(tmp, "arrays.npz"), **dict(host))
                with open(os.path.join(tmp, "meta.json"), "w") as f:
                    json.dump(info, f)
                with open(os.path.join(tmp, _COMMIT), "w") as f:
                    f.write(str(step))
                os.rename(tmp, final)
                self._retain()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        """Block until the in-flight save (if any) commits."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            e, self._error = self._error, None
            raise e

    # -- retention --------------------------------------------------------------
    def _retain(self) -> None:
        steps = sorted(
            int(n.split("_", 1)[1]) for n in os.listdir(self.ckpt_dir)
            if n.startswith("step_") and not n.endswith(".tmp")
            and os.path.exists(os.path.join(self.ckpt_dir, n, _COMMIT)))
        for s in steps[:-self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.ckpt_dir, f"step_{s}"),
                          ignore_errors=True)

    # -- restore ------------------------------------------------------------------
    def restore_latest(self, template: PyTree,
                       shardings: Optional[PyTree] = None
                       ) -> Tuple[Optional[int], Optional[PyTree]]:
        step = latest_step(self.ckpt_dir)
        if step is None:
            return None, None
        return step, restore(self.ckpt_dir, step, template, shardings)
