from repro.checkpoint.manager import CheckpointManager, latest_step, restore
from repro.checkpoint.elastic import reshard_restore

__all__ = ["CheckpointManager", "latest_step", "restore", "reshard_restore"]
