"""Shared model primitives: norms, inits, parameter builder with logical axes.

Parameters are plain nested dicts of jnp arrays. Every parameter carries a
tuple of *logical axis names* (e.g. ``("embed", "ff")``) in a parallel tree;
``repro.runtime.mesh_util`` maps logical names to mesh axes per run, which is
how one model definition serves every (shape x mesh) cell.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dtype)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dtype)


def softcap(x: jax.Array, cap: float) -> jax.Array:
    if cap <= 0:
        return x
    return jnp.tanh(x / cap) * cap


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


class ParamBuilder:
    """Accumulates parameters + their logical axes under nested name scopes."""

    def __init__(self, key: jax.Array, dtype=jnp.float32):
        self._key = key
        self.dtype = dtype
        self.params: Dict[str, Any] = {}
        self.axes: Dict[str, Any] = {}
        self._scope: list = []

    def _split(self) -> jax.Array:
        self._key, sub = jax.random.split(self._key)
        return sub

    def scope(self, name: str) -> "ParamBuilder":
        child = ParamBuilder.__new__(ParamBuilder)
        child._key = self._split()
        child.dtype = self.dtype
        d_p: Dict[str, Any] = {}
        d_a: Dict[str, Any] = {}
        self.params[name] = d_p
        self.axes[name] = d_a
        child.params = d_p
        child.axes = d_a
        child._scope = self._scope + [name]
        return child

    def param(self, name: str, shape: Tuple[int, ...], axes: Tuple[Optional[str], ...],
              init: str = "normal", fan_in: Optional[int] = None) -> jax.Array:
        assert len(shape) == len(axes), (name, shape, axes)
        if init == "zeros":
            val = jnp.zeros(shape, self.dtype)
        elif init == "ones":
            val = jnp.ones(shape, self.dtype)
        else:
            fi = fan_in if fan_in is not None else (shape[0] if len(shape) > 1 else shape[-1])
            std = 1.0 / math.sqrt(max(1, fi))
            val = (jax.random.normal(self._split(), shape, jnp.float32) * std).astype(self.dtype)
        self.params[name] = val
        self.axes[name] = axes
        return val


def stack_params(trees: list) -> PyTree:
    """Stack a list of identically-structured param trees along a new axis 0."""
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *trees)


def stack_axes(axes_tree: PyTree, name: str = "layer") -> PyTree:
    """Prepend a stacking logical axis to every leaf of an axes tree."""
    return jax.tree.map(
        lambda a: (name,) + tuple(a),
        axes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(e, (str, type(None))) for e in x),
    )


def count_params(params: PyTree) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(params))
