"""KV-cache structures for decode. Registered as pytrees so they flow through jit.

Three layouts:
  * ``KVCache``      — standard GQA: k/v (B, S_max, K, D), contiguous per row.
  * ``MLACache``     — deepseek MLA: compressed c_kv (B, S_max, r) + shared rope
    key (B, S_max, rope_dim); ~(2*K*D)/(r+rope) smaller than materialized k/v.
  * ``PagedKVCache`` — serving: one shared block pool (N_blocks, block_size,
    K, D) per layer; requests own blocks through a per-request block table
    so HBM is allocated at actual-sequence-length granularity instead of
    ``slots * max_len`` (the receiver-resident-state pool of docs/serving.md).

Sliding-window layers may allocate ``S_max = window`` and write via ring
indexing (``ring=True``) — the beyond-paper memory optimization for long
contexts (EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class KVCache:
    k: jax.Array                     # (B, S_max, K, D)
    v: jax.Array                     # (B, S_max, K, D)
    length: jax.Array                # () int32 — tokens already in cache
    ring: bool = dataclasses.field(default=False, metadata=dict(static=True))

    @property
    def max_len(self) -> int:
        return self.k.shape[1]

    @staticmethod
    def init(batch: int, max_len: int, kv_heads: int, head_dim: int,
             dtype=jnp.bfloat16, ring: bool = False) -> "KVCache":
        shape = (batch, max_len, kv_heads, head_dim)
        return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype),
                       jnp.zeros((), jnp.int32), ring)

    def append(self, k_new: jax.Array, v_new: jax.Array) -> "KVCache":
        """Append S_new tokens (B, S_new, K, D) at position ``length``.

        Ring caches with a multi-token append must NOT use a single
        ``dynamic_update_slice``: DUS clamps the start index so the slice
        stays in bounds instead of wrapping, which silently shifts every
        token written across the wrap boundary. Those appends scatter to
        explicit ``(length + i) % max_len`` rows instead; a single-token
        ring append can never cross the boundary and keeps the DUS fast
        path.
        """
        s_new = k_new.shape[1]
        new_len = self.length + s_new
        if self.ring and s_new > 1:
            if s_new >= self.max_len:
                # only the last max_len tokens survive a full wrap — drop
                # the overwritten prefix so scatter rows are unique
                k_new = k_new[:, -self.max_len:]
                v_new = v_new[:, -self.max_len:]
                s_new = self.max_len
            # surviving tokens occupy absolute positions [new_len - s_new,
            # new_len); map each to its ring row
            rows = (new_len - s_new
                    + jnp.arange(s_new, dtype=jnp.int32)) % self.max_len
            k = self.k.at[:, rows].set(k_new.astype(self.k.dtype))
            v = self.v.at[:, rows].set(v_new.astype(self.v.dtype))
            return KVCache(k, v, new_len, self.ring)
        pos = self.length % self.max_len if self.ring else self.length
        k = jax.lax.dynamic_update_slice(self.k, k_new.astype(self.k.dtype), (0, pos, 0, 0))
        v = jax.lax.dynamic_update_slice(self.v, v_new.astype(self.v.dtype), (0, pos, 0, 0))
        return KVCache(k, v, new_len, self.ring)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class MLACache:
    c_kv: jax.Array                  # (B, S_max, r)
    k_rope: jax.Array                # (B, S_max, rope_dim)
    length: jax.Array                # () int32

    @property
    def max_len(self) -> int:
        return self.c_kv.shape[1]

    @staticmethod
    def init(batch: int, max_len: int, kv_lora_rank: int, rope_dim: int,
             dtype=jnp.bfloat16) -> "MLACache":
        return MLACache(
            jnp.zeros((batch, max_len, kv_lora_rank), dtype),
            jnp.zeros((batch, max_len, rope_dim), dtype),
            jnp.zeros((), jnp.int32),
        )

    def append(self, c_new: jax.Array, kr_new: jax.Array) -> "MLACache":
        c = jax.lax.dynamic_update_slice(self.c_kv, c_new.astype(self.c_kv.dtype), (0, self.length, 0))
        kr = jax.lax.dynamic_update_slice(self.k_rope, kr_new.astype(self.k_rope.dtype), (0, self.length, 0))
        return MLACache(c, kr, self.length + c_new.shape[1])


class PagedLayout(NamedTuple):
    """Per-step view of the paged pool, built inside the jitted step.

    block_tables: (B, max_blocks) int32 — pool block ids per request, in
        logical order; -1 marks unallocated slots.
    starts: (B,) int32 — tokens already resident per request (the absolute
        position of this step's first new token).
    n_valid: (B,) int32 — how many of this step's ``chunk`` token columns
        are real for each request (decode rows use 1, prefill rows up to
        ``chunk``, idle rows 0).
    block_size: static python int — tokens per pool block.
    """

    block_tables: jax.Array
    starts: jax.Array
    n_valid: jax.Array
    block_size: int

    def token_positions(self, chunk: int) -> jax.Array:
        return (self.starts[:, None]
                + jnp.arange(chunk, dtype=jnp.int32)[None, :])

    def token_valid(self, chunk: int) -> jax.Array:
        return (jnp.arange(chunk, dtype=jnp.int32)[None, :]
                < self.n_valid[:, None])


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class PagedKVCache:
    """Block-pool GQA cache: requests gather/scatter through a block table.

    The pool is shared by every request; logical position ``p`` of request
    ``b`` lives at ``(block_tables[b, p // block_size], p % block_size)``.
    All ops are fixed-shape (jit-friendly): invalid writes scatter out of
    bounds and are dropped, invalid reads are masked by the caller.
    """

    k_pool: jax.Array                # (N_blocks, block_size, K, D)
    v_pool: jax.Array
    block_size: int = dataclasses.field(default=16, metadata=dict(static=True))

    @property
    def num_blocks(self) -> int:
        return self.k_pool.shape[0]

    @staticmethod
    def init(num_blocks: int, block_size: int, kv_heads: int, head_dim: int,
             dtype=jnp.bfloat16) -> "PagedKVCache":
        shape = (num_blocks, block_size, kv_heads, head_dim)
        return PagedKVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype),
                            block_size)

    def _dest_rows(self, layout: PagedLayout, chunk: int) -> jax.Array:
        """Flat pool-row index per (request, token column); OOB when invalid."""
        bs = self.block_size
        pos = layout.token_positions(chunk)                    # (B, C)
        blk_idx = jnp.clip(pos // bs, 0, layout.block_tables.shape[1] - 1)
        blk = jnp.take_along_axis(layout.block_tables, blk_idx, axis=1)
        rows = blk * bs + pos % bs
        oob = self.num_blocks * bs                             # dropped by .at
        return jnp.where(layout.token_valid(chunk) & (blk >= 0), rows, oob)

    def write(self, k_new: jax.Array, v_new: jax.Array,
              layout: PagedLayout) -> "PagedKVCache":
        """Scatter (B, C, K, D) new tokens into the pool at their logical
        positions; invalid columns (beyond ``n_valid``) are dropped."""
        chunk = k_new.shape[1]
        rows = self._dest_rows(layout, chunk).reshape(-1)
        tail = self.k_pool.shape[2:]
        flat_k = self.k_pool.reshape(-1, *tail)
        flat_v = self.v_pool.reshape(-1, *tail)
        flat_k = flat_k.at[rows].set(
            k_new.reshape(-1, *tail).astype(flat_k.dtype), mode="drop")
        flat_v = flat_v.at[rows].set(
            v_new.reshape(-1, *tail).astype(flat_v.dtype), mode="drop")
        return PagedKVCache(flat_k.reshape(self.k_pool.shape),
                            flat_v.reshape(self.v_pool.shape),
                            self.block_size)

    def gather(self, block_tables: jax.Array,
               seq_lens: Optional[jax.Array] = None):
        """Materialize each request's logical (T, K, D) view, T = M * bs.

        Unallocated table slots (-1) read block 0 — callers mask positions
        ``>= length`` so the garbage never reaches the softmax unmasked.

        With ``seq_lens`` (per-request resident-token counts, (B,)), also
        returns ``max_resident``: the longest live sequence rounded up to
        ``block_size`` and clamped to T. Eager callers (the kernel oracle,
        tests) use it to bound the view to live tokens instead of always
        ``max_blocks * block_size``; under jit it is a tracer and the full
        fixed-shape view stands.
        """
        bs = self.block_size
        B, M = block_tables.shape
        rows = (jnp.clip(block_tables, 0)[:, :, None] * bs
                + jnp.arange(bs, dtype=jnp.int32)[None, None, :])
        rows = rows.reshape(B, M * bs)
        tail = self.k_pool.shape[2:]
        flat_k = self.k_pool.reshape(-1, *tail)
        flat_v = self.v_pool.reshape(-1, *tail)
        if seq_lens is None:
            return flat_k[rows], flat_v[rows]
        max_resident = jnp.minimum(
            -(-jnp.max(seq_lens.astype(jnp.int32)) // bs) * bs, M * bs)
        return flat_k[rows], flat_v[rows], max_resident


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class SSMCache:
    """Recurrent state for mamba / xLSTM decode: O(1) in sequence length."""
    conv: jax.Array                  # (B, conv_width-1, inner) rolling conv inputs
    state: jax.Array                 # (B, ...) recurrent state
    extra: Any                       # e.g. sLSTM normalizer / mLSTM (n, m) terms
    length: jax.Array
