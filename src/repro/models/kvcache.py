"""KV-cache structures for decode. Registered as pytrees so they flow through jit.

Three layouts:
  * ``KVCache``      — standard GQA: k/v (B, S_max, K, D), contiguous per row.
  * ``MLACache``     — deepseek MLA: compressed c_kv (B, S_max, r) + shared rope
    key (B, S_max, rope_dim); ~(2*K*D)/(r+rope) smaller than materialized k/v.
  * ``PagedKVCache`` — serving: one shared block pool (N_blocks, block_size,
    K, D) per layer; requests own blocks through a per-request block table
    so HBM is allocated at actual-sequence-length granularity instead of
    ``slots * max_len`` (the receiver-resident-state pool of docs/serving.md).

Sliding-window layers may allocate ``S_max = window`` and write via ring
indexing (``ring=True``) — the beyond-paper memory optimization for long
contexts (EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import dataclasses
import json
import struct
from typing import (Any, Callable, Dict, NamedTuple, Optional, Protocol,
                    Tuple, runtime_checkable)

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class KVCache:
    k: jax.Array                     # (B, S_max, K, D)
    v: jax.Array                     # (B, S_max, K, D)
    length: jax.Array                # () int32 — tokens already in cache
    ring: bool = dataclasses.field(default=False, metadata=dict(static=True))

    @property
    def max_len(self) -> int:
        return self.k.shape[1]

    @staticmethod
    def init(batch: int, max_len: int, kv_heads: int, head_dim: int,
             dtype=jnp.bfloat16, ring: bool = False) -> "KVCache":
        shape = (batch, max_len, kv_heads, head_dim)
        return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype),
                       jnp.zeros((), jnp.int32), ring)

    def append(self, k_new: jax.Array, v_new: jax.Array) -> "KVCache":
        """Append S_new tokens (B, S_new, K, D) at position ``length``.

        Ring caches with a multi-token append must NOT use a single
        ``dynamic_update_slice``: DUS clamps the start index so the slice
        stays in bounds instead of wrapping, which silently shifts every
        token written across the wrap boundary. Those appends scatter to
        explicit ``(length + i) % max_len`` rows instead; a single-token
        ring append can never cross the boundary and keeps the DUS fast
        path.
        """
        s_new = k_new.shape[1]
        new_len = self.length + s_new
        if self.ring and s_new > 1:
            if s_new >= self.max_len:
                # only the last max_len tokens survive a full wrap — drop
                # the overwritten prefix so scatter rows are unique
                k_new = k_new[:, -self.max_len:]
                v_new = v_new[:, -self.max_len:]
                s_new = self.max_len
            # surviving tokens occupy absolute positions [new_len - s_new,
            # new_len); map each to its ring row
            rows = (new_len - s_new
                    + jnp.arange(s_new, dtype=jnp.int32)) % self.max_len
            k = self.k.at[:, rows].set(k_new.astype(self.k.dtype))
            v = self.v.at[:, rows].set(v_new.astype(self.v.dtype))
            return KVCache(k, v, new_len, self.ring)
        pos = self.length % self.max_len if self.ring else self.length
        k = jax.lax.dynamic_update_slice(self.k, k_new.astype(self.k.dtype), (0, pos, 0, 0))
        v = jax.lax.dynamic_update_slice(self.v, v_new.astype(self.v.dtype), (0, pos, 0, 0))
        return KVCache(k, v, new_len, self.ring)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class MLACache:
    c_kv: jax.Array                  # (B, S_max, r)
    k_rope: jax.Array                # (B, S_max, rope_dim)
    length: jax.Array                # () int32

    @property
    def max_len(self) -> int:
        return self.c_kv.shape[1]

    @staticmethod
    def init(batch: int, max_len: int, kv_lora_rank: int, rope_dim: int,
             dtype=jnp.bfloat16) -> "MLACache":
        return MLACache(
            jnp.zeros((batch, max_len, kv_lora_rank), dtype),
            jnp.zeros((batch, max_len, rope_dim), dtype),
            jnp.zeros((), jnp.int32),
        )

    def append(self, c_new: jax.Array, kr_new: jax.Array) -> "MLACache":
        c = jax.lax.dynamic_update_slice(self.c_kv, c_new.astype(self.c_kv.dtype), (0, self.length, 0))
        kr = jax.lax.dynamic_update_slice(self.k_rope, kr_new.astype(self.k_rope.dtype), (0, self.length, 0))
        return MLACache(c, kr, self.length + c_new.shape[1])


class PagedLayout(NamedTuple):
    """Per-step view of the paged pool, built inside the jitted step.

    block_tables: (B, max_blocks) int32 — pool block ids per request, in
        logical order; -1 marks unallocated slots.
    starts: (B,) int32 — tokens already resident per request (the absolute
        position of this step's first new token).
    n_valid: (B,) int32 — how many of this step's ``chunk`` token columns
        are real for each request (decode rows use 1, prefill rows up to
        ``chunk``, idle rows 0).
    block_size: static python int — tokens per pool block.
    """

    block_tables: jax.Array
    starts: jax.Array
    n_valid: jax.Array
    block_size: int

    def token_positions(self, chunk: int) -> jax.Array:
        return (self.starts[:, None]
                + jnp.arange(chunk, dtype=jnp.int32)[None, :])

    def token_valid(self, chunk: int) -> jax.Array:
        return (jnp.arange(chunk, dtype=jnp.int32)[None, :]
                < self.n_valid[:, None])


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class PagedKVCache:
    """Block-pool GQA cache: requests gather/scatter through a block table.

    The pool is shared by every request; logical position ``p`` of request
    ``b`` lives at ``(block_tables[b, p // block_size], p % block_size)``.
    All ops are fixed-shape (jit-friendly): invalid writes scatter out of
    bounds and are dropped, invalid reads are masked by the caller.
    """

    k_pool: jax.Array                # (N_blocks, block_size, K, D)
    v_pool: jax.Array
    block_size: int = dataclasses.field(default=16, metadata=dict(static=True))

    @property
    def num_blocks(self) -> int:
        return self.k_pool.shape[0]

    @staticmethod
    def init(num_blocks: int, block_size: int, kv_heads: int, head_dim: int,
             dtype=jnp.bfloat16) -> "PagedKVCache":
        shape = (num_blocks, block_size, kv_heads, head_dim)
        return PagedKVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype),
                            block_size)

    def _dest_rows(self, layout: PagedLayout, chunk: int) -> jax.Array:
        """Flat pool-row index per (request, token column); OOB when invalid."""
        bs = self.block_size
        pos = layout.token_positions(chunk)                    # (B, C)
        blk_idx = jnp.clip(pos // bs, 0, layout.block_tables.shape[1] - 1)
        blk = jnp.take_along_axis(layout.block_tables, blk_idx, axis=1)
        rows = blk * bs + pos % bs
        oob = self.num_blocks * bs                             # dropped by .at
        return jnp.where(layout.token_valid(chunk) & (blk >= 0), rows, oob)

    def write(self, k_new: jax.Array, v_new: jax.Array,
              layout: PagedLayout) -> "PagedKVCache":
        """Scatter (B, C, K, D) new tokens into the pool at their logical
        positions; invalid columns (beyond ``n_valid``) are dropped."""
        chunk = k_new.shape[1]
        rows = self._dest_rows(layout, chunk).reshape(-1)
        tail = self.k_pool.shape[2:]
        flat_k = self.k_pool.reshape(-1, *tail)
        flat_v = self.v_pool.reshape(-1, *tail)
        flat_k = flat_k.at[rows].set(
            k_new.reshape(-1, *tail).astype(flat_k.dtype), mode="drop")
        flat_v = flat_v.at[rows].set(
            v_new.reshape(-1, *tail).astype(flat_v.dtype), mode="drop")
        return PagedKVCache(flat_k.reshape(self.k_pool.shape),
                            flat_v.reshape(self.v_pool.shape),
                            self.block_size)

    def gather(self, block_tables: jax.Array,
               seq_lens: Optional[jax.Array] = None):
        """Materialize each request's logical (T, K, D) view, T = M * bs.

        Unallocated table slots (-1) read block 0 — callers mask positions
        ``>= length`` so the garbage never reaches the softmax unmasked.

        With ``seq_lens`` (per-request resident-token counts, (B,)), also
        returns ``max_resident``: the longest live sequence rounded up to
        ``block_size`` and clamped to T. Eager callers (the kernel oracle,
        tests) use it to bound the view to live tokens instead of always
        ``max_blocks * block_size``; under jit it is a tracer and the full
        fixed-shape view stands.
        """
        bs = self.block_size
        B, M = block_tables.shape
        rows = (jnp.clip(block_tables, 0)[:, :, None] * bs
                + jnp.arange(bs, dtype=jnp.int32)[None, None, :])
        rows = rows.reshape(B, M * bs)
        tail = self.k_pool.shape[2:]
        flat_k = self.k_pool.reshape(-1, *tail)
        flat_v = self.v_pool.reshape(-1, *tail)
        if seq_lens is None:
            return flat_k[rows], flat_v[rows]
        max_resident = jnp.minimum(
            -(-jnp.max(seq_lens.astype(jnp.int32)) // bs) * bs, M * bs)
        return flat_k[rows], flat_v[rows], max_resident


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class SSMCache:
    """Recurrent state for mamba / xLSTM decode: O(1) in sequence length."""
    conv: jax.Array                  # (B, conv_width-1, inner) rolling conv inputs
    state: jax.Array                 # (B, ...) recurrent state
    extra: Any                       # e.g. sLSTM normalizer / mLSTM (n, m) terms
    length: jax.Array


class RecurrentLayout(NamedTuple):
    """Per-step serving view for recurrent (SSM/xLSTM) stacks.

    The recurrent counterpart of ``PagedLayout`` minus the block tables:
    state is constant-size per request, so the only per-step facts are
    where each row is in its sequence and how many of the ``chunk`` token
    columns are real.

    starts: (B,) int32 — tokens already absorbed into the state per row.
    n_valid: (B,) int32 — real token columns this step (decode rows 1,
        prefill rows up to ``chunk``, idle rows 0).
    """

    starts: jax.Array
    n_valid: jax.Array

    def token_positions(self, chunk: int) -> jax.Array:
        return (self.starts[:, None]
                + jnp.arange(chunk, dtype=jnp.int32)[None, :])

    def token_valid(self, chunk: int) -> jax.Array:
        return (jnp.arange(chunk, dtype=jnp.int32)[None, :]
                < self.n_valid[:, None])


# ---------------------------------------------------------------------------
# state serialization (the migration seam: ROADMAP item 3)
# ---------------------------------------------------------------------------

_STATE_MAGIC = b"RST1"


def _np_dtype(name: str) -> np.dtype:
    if name == "bfloat16":
        return np.dtype(jnp.bfloat16)
    return np.dtype(name)


def state_to_bytes(tree: Any) -> bytes:
    """Pack a pytree of arrays into one buffer: a JSON header (per-leaf
    dtype + shape, in ``tree_leaves`` order) followed by the raw bytes.

    The tree *structure* does not travel — sender and receiver agree on it
    out of band (same model config), exactly like the GOT layout hash of
    docs/fabric.md; only values cross the wire. bf16 round-trips exactly
    (raw ml_dtypes bytes, no float32 detour)."""
    arrs = [np.asarray(leaf) for leaf in jax.tree_util.tree_leaves(tree)]
    header = json.dumps([{"dtype": a.dtype.name, "shape": list(a.shape)}
                         for a in arrs]).encode("utf-8")
    parts = [_STATE_MAGIC, struct.pack("<I", len(header)), header]
    parts.extend(np.ascontiguousarray(a).tobytes() for a in arrs)
    return b"".join(parts)


def state_from_bytes(buf: bytes, like: Any) -> Any:
    """Inverse of ``state_to_bytes``. ``like`` supplies the tree structure
    (arrays or ShapeDtypeStructs); leaf dtype/shape mismatches between the
    buffer and ``like`` raise rather than silently reinterpreting bytes."""
    if buf[:4] != _STATE_MAGIC:
        raise ValueError("state buffer does not start with the RST1 magic")
    (hlen,) = struct.unpack("<I", buf[4:8])
    header = json.loads(buf[8:8 + hlen].decode("utf-8"))
    like_leaves, treedef = jax.tree_util.tree_flatten(like)
    if len(header) != len(like_leaves):
        raise ValueError(
            f"state buffer holds {len(header)} leaves, template has "
            f"{len(like_leaves)}")
    off = 8 + hlen
    out = []
    for meta, ref in zip(header, like_leaves):
        dtype = _np_dtype(meta["dtype"])
        shape = tuple(meta["shape"])
        if (tuple(ref.shape) != shape
                or np.dtype(ref.dtype).name != dtype.name):
            raise ValueError(
                f"state leaf mismatch: buffer has {meta['dtype']}{shape}, "
                f"template expects "
                f"{np.dtype(ref.dtype).name}{tuple(ref.shape)}")
        n = int(np.prod(shape, dtype=np.int64)) * dtype.itemsize
        arr = np.frombuffer(buf[off:off + n], dtype=dtype).reshape(shape)
        off += n
        out.append(jnp.asarray(arr))
    if off != len(buf):
        raise ValueError(f"state buffer has {len(buf) - off} trailing bytes")
    return jax.tree_util.tree_unflatten(treedef, out)


def ssm_cache_to_bytes(cache: SSMCache) -> bytes:
    """Serialize one ``SSMCache`` (conv + state + extra + length)."""
    return state_to_bytes(cache)


def ssm_cache_from_bytes(buf: bytes, like: SSMCache) -> SSMCache:
    """Rebuild an ``SSMCache`` from ``ssm_cache_to_bytes`` output; ``like``
    provides the structure (an init-shaped cache works)."""
    return state_from_bytes(buf, like)


# ---------------------------------------------------------------------------
# SequenceState — the per-request sequence-state backend protocol
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SequenceCapacity:
    """What a backend's admission-limiting resource looks like.

    ``free_units is None`` means the resource is not consumable (a slot
    row or constant-size state exists per slot regardless of sequence
    length) and admission is gated on free slots alone."""

    kind: str                        # backend name ("paged"/"slots"/...)
    unit: str                        # "blocks" | "slots"
    total_units: Optional[int]
    free_units: Optional[int]


@runtime_checkable
class SequenceState(Protocol):
    """Pluggable per-request sequence-state backend for the Engine.

    The engine owns requests and the tick loop; the backend owns what a
    request's *state* is and what it costs: pool blocks (``PagedKVState``),
    a contiguous cache row (``SlotKVState``), or constant-size recurrent
    state (``RecurrentState``). Entries are duck-typed scheduler records
    (``pos``/``blocks``/``snapshot``/``seq()``); ``cache`` is the live
    device pytree, threaded through because several backends rebuild it.
    """

    kind: str
    supports_preemption: bool

    def init(self, entry: Any, cache: Any, slot: int) -> Any:
        """Prepare ``slot`` for ``entry`` at admission; returns the cache."""

    def append(self, entry: Any, n: int) -> None:
        """Host-side accounting after ``n`` tokens entered the state."""

    def gather(self, entry: Any, cache: Any, slot: int) -> Any:
        """Materialize the request's state as a host pytree."""

    def units_needed(self, entry: Any) -> int:
        """Capacity units required to advance this entry one step."""

    def grow(self, entry: Any, upto_tokens: int) -> bool:
        """Reserve capacity for ``upto_tokens``; False when exhausted."""

    def evict(self, entry: Any, cache: Any, slot: int) -> Any:
        """Release/park the entry's state for requeue; returns the cache."""

    def release(self, entry: Any) -> None:
        """Drop all state owned by a finished entry."""

    def serialize(self, entry: Any, cache: Any, slot: int) -> bytes:
        """The migration seam: the request's state as one buffer."""

    def restore(self, entry: Any, cache: Any, slot: int, buf: bytes) -> Any:
        """Inverse of ``serialize``: write a migrated request's state into
        ``slot``. The buffer is position-independent (logical token order,
        no physical block ids / slot indices), so source and target may
        disagree on pool geometry, block allocation, and slot number — only
        the model config and this backend's *kind* must match. Returns the
        updated cache; the entry must already own whatever capacity the
        resident prefix needs (the engine grows it before restoring)."""

    def capacity(self) -> SequenceCapacity: ...

    def metrics(self) -> Dict[str, Any]: ...

    def validate(self, prompt_len: int, max_new: int,
                 max_len: int) -> Optional[str]:
        """Reject-at-submit check; an error string or None."""


def slot_axis(live_shape: Tuple[int, ...], one_shape: Tuple[int, ...],
              slots: int) -> Optional[int]:
    """Locate the batch (slot) axis of a cache leaf structurally: the first
    axis where the live leaf has ``slots`` extent, the one-row template has
    extent 1, and every leading dim matches. (Same rule as the Engine's
    prefill scatter: positional guesses mistake the layer-stack dim for
    batch.) Returns None for leaves with no per-slot axis (scalars)."""
    if len(live_shape) != len(one_shape):
        return None
    for ax in range(len(live_shape)):
        if (live_shape[ax] == slots and one_shape[ax] == 1
                and live_shape[:ax] == one_shape[:ax]):
            return ax
    return None


def gather_slot_rows(cache: Any, template: Any, slot: int, slots: int) -> Any:
    """Slice one slot's rows out of a batched cache (host numpy pytree).
    Leaves without a slot axis (the shared length scalar) copy through."""
    def take(live, one):
        ax = slot_axis(tuple(live.shape), tuple(np.shape(one)), slots)
        if ax is None:
            return np.asarray(live)
        return np.asarray(jax.lax.dynamic_slice_in_dim(live, slot, 1, axis=ax))
    return jax.tree.map(take, cache, template)


def scatter_slot_rows(cache: Any, row: Any, slot: int, slots: int) -> Any:
    """Write one-row state back into ``slot`` of a batched cache. Leaves
    without a slot axis are left untouched."""
    def put(live, one):
        ax = slot_axis(tuple(live.shape), tuple(np.shape(one)), slots)
        if ax is None:
            return live
        start = [0] * live.ndim
        start[ax] = slot
        return jax.lax.dynamic_update_slice(
            live, jnp.asarray(one).astype(live.dtype), tuple(start))
    return jax.tree.map(put, cache, row)


class RecurrentState:
    """``SequenceState`` over constant-size recurrent state (SSM/xLSTM).

    A request's entire sequence state is its ``SSMCache`` rows — O(1) in
    sequence length — so there is no consumable pool: ``grow`` always
    succeeds and admission is gated on free slots alone. Eviction is a
    cheap host snapshot of the slot's rows (``entry.snapshot``); on
    re-admission the snapshot is scattered back and decoding resumes where
    it stopped — never a recompute, which is what makes preemption (and
    ROADMAP item 3's migration) nearly free for these model families.

    ``template_fn`` returns a one-row init cache (NOT zeros: mLSTM carries
    ``m = -inf``, sLSTM ``n = 1``); it also clears a freed slot's stale
    state before a fresh request runs, since recurrent updates would
    otherwise integrate the previous occupant's state.
    """

    kind = "recurrent"
    supports_preemption = True

    def __init__(self, slots: int, template_fn: Callable[[], Any],
                 place: Optional[Callable[[Any], Any]] = None):
        self.slots = slots
        self._template_fn = template_fn
        self._template: Any = None
        self._place = place if place is not None else (lambda t: t)
        self.snapshots_taken = 0
        self.snapshots_restored = 0

    @property
    def template(self) -> Any:
        if self._template is None:
            self._template = jax.tree.map(np.asarray, self._template_fn())
        return self._template

    def state_bytes_per_slot(self) -> int:
        return sum(leaf.nbytes
                   for leaf in jax.tree_util.tree_leaves(self.template)
                   if getattr(leaf, "ndim", 0) > 0)

    def init(self, entry: Any, cache: Any, slot: int) -> Any:
        row = getattr(entry, "snapshot", None)
        restored = row is not None
        if row is None:
            row = self.template
        cache = scatter_slot_rows(cache, row, slot, self.slots)
        if restored:
            entry.snapshot = None
            self.snapshots_restored += 1
        return self._place(cache)

    def append(self, entry: Any, n: int) -> None:
        return None

    def gather(self, entry: Any, cache: Any, slot: int) -> Any:
        return gather_slot_rows(cache, self.template, slot, self.slots)

    def units_needed(self, entry: Any) -> int:
        return 0

    def grow(self, entry: Any, upto_tokens: int) -> bool:
        return True

    def evict(self, entry: Any, cache: Any, slot: int) -> Any:
        # snapshot covers seq[:entry.pos]; pos is deliberately kept so
        # re-admission resumes (feed the next unseen token) instead of
        # re-prefilling — the opposite of the paged recompute path
        entry.snapshot = self.gather(entry, cache, slot)
        self.snapshots_taken += 1
        return cache

    def release(self, entry: Any) -> None:
        if getattr(entry, "snapshot", None) is not None:
            entry.snapshot = None

    def serialize(self, entry: Any, cache: Any, slot: int) -> bytes:
        return state_to_bytes(self.gather(entry, cache, slot))

    def restore(self, entry: Any, cache: Any, slot: int, buf: bytes) -> Any:
        """Scatter a migrated request's state rows into ``slot`` — the
        byte-level twin of the snapshot-resume path (``init`` with
        ``entry.snapshot``), so a migrated request resumes exactly like a
        requeued one: state absorbed through ``entry.pos``, never a
        recompute. Constant-size state is what makes recurrent migration
        nearly free (a few KB regardless of sequence length)."""
        row = state_from_bytes(buf, self.template)
        return self._place(scatter_slot_rows(cache, row, slot, self.slots))

    def capacity(self) -> SequenceCapacity:
        return SequenceCapacity(kind="recurrent", unit="slots",
                                total_units=self.slots, free_units=None)

    def metrics(self) -> Dict[str, Any]:
        return {
            "state_bytes_per_slot": self.state_bytes_per_slot(),
            "snapshots_taken": self.snapshots_taken,
            "snapshots_restored": self.snapshots_restored,
        }

    def validate(self, prompt_len: int, max_new: int,
                 max_len: int) -> Optional[str]:
        return None                  # constant-size state: no length limit
