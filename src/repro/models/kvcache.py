"""KV-cache structures for decode. Registered as pytrees so they flow through jit.

Two layouts:
  * ``KVCache``  — standard GQA: k/v (B, S_max, K, D).
  * ``MLACache`` — deepseek MLA: compressed c_kv (B, S_max, r) + shared rope
    key (B, S_max, rope_dim); ~(2*K*D)/(r+rope) smaller than materialized k/v.

Sliding-window layers may allocate ``S_max = window`` and write via ring
indexing (``ring=True``) — the beyond-paper memory optimization for long
contexts (EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class KVCache:
    k: jax.Array                     # (B, S_max, K, D)
    v: jax.Array                     # (B, S_max, K, D)
    length: jax.Array                # () int32 — tokens already in cache
    ring: bool = dataclasses.field(default=False, metadata=dict(static=True))

    @property
    def max_len(self) -> int:
        return self.k.shape[1]

    @staticmethod
    def init(batch: int, max_len: int, kv_heads: int, head_dim: int,
             dtype=jnp.bfloat16, ring: bool = False) -> "KVCache":
        shape = (batch, max_len, kv_heads, head_dim)
        return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype),
                       jnp.zeros((), jnp.int32), ring)

    def append(self, k_new: jax.Array, v_new: jax.Array) -> "KVCache":
        """Append S_new tokens (B, S_new, K, D) at position ``length``."""
        pos = self.length % self.max_len if self.ring else self.length
        k = jax.lax.dynamic_update_slice(self.k, k_new.astype(self.k.dtype), (0, pos, 0, 0))
        v = jax.lax.dynamic_update_slice(self.v, v_new.astype(self.v.dtype), (0, pos, 0, 0))
        return KVCache(k, v, self.length + k_new.shape[1], self.ring)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class MLACache:
    c_kv: jax.Array                  # (B, S_max, r)
    k_rope: jax.Array                # (B, S_max, rope_dim)
    length: jax.Array                # () int32

    @property
    def max_len(self) -> int:
        return self.c_kv.shape[1]

    @staticmethod
    def init(batch: int, max_len: int, kv_lora_rank: int, rope_dim: int,
             dtype=jnp.bfloat16) -> "MLACache":
        return MLACache(
            jnp.zeros((batch, max_len, kv_lora_rank), dtype),
            jnp.zeros((batch, max_len, rope_dim), dtype),
            jnp.zeros((), jnp.int32),
        )

    def append(self, c_new: jax.Array, kr_new: jax.Array) -> "MLACache":
        c = jax.lax.dynamic_update_slice(self.c_kv, c_new.astype(self.c_kv.dtype), (0, self.length, 0))
        kr = jax.lax.dynamic_update_slice(self.k_rope, kr_new.astype(self.k_rope.dtype), (0, self.length, 0))
        return MLACache(c, kr, self.length + c_new.shape[1])


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class SSMCache:
    """Recurrent state for mamba / xLSTM decode: O(1) in sequence length."""
    conv: jax.Array                  # (B, conv_width-1, inner) rolling conv inputs
    state: jax.Array                 # (B, ...) recurrent state
    extra: Any                       # e.g. sLSTM normalizer / mLSTM (n, m) terms
    length: jax.Array
