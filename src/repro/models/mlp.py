"""Dense FFN: gated (SwiGLU-style) and classic 2-matrix MLP."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import ParamBuilder, act_fn


def init_mlp(b: ParamBuilder, d_model: int, d_ff: int, gated: bool = True) -> None:
    if gated:
        b.param("w_gate", (d_model, d_ff), ("embed", "ff"))
    b.param("w_up", (d_model, d_ff), ("embed", "ff"))
    b.param("w_down", (d_ff, d_model), ("ff", "embed"))


def mlp(params, x: jax.Array, act: str = "silu", gated: bool = True) -> jax.Array:
    up = jnp.einsum("bsd,df->bsf", x, params["w_up"])
    if gated:
        gate = jnp.einsum("bsd,df->bsf", x, params["w_gate"])
        h = act_fn(act)(gate) * up
    else:
        h = act_fn(act)(up)
    return jnp.einsum("bsf,fd->bsd", h, params["w_down"])
