"""Mamba-style selective SSM block (hymba's SSM heads).

Train/prefill use a chunk-free ``lax.scan`` over time (small HLO; the Pallas
``ssm_scan`` kernel is the TPU perf path). Decode carries ``SSMCache`` — the
O(1)-state property that makes long_500k runnable for hybrid/ssm archs.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import SSMConfig
from repro.models.common import ParamBuilder
from repro.models.kvcache import SSMCache


def init_ssm(b: ParamBuilder, d_model: int, s: SSMConfig) -> None:
    inner = s.expand * d_model
    dt_rank = s.dt_rank or -(-d_model // 16)
    b.param("in_proj", (d_model, 2 * inner), ("embed", "ff"))
    b.param("conv_w", (s.conv_width, inner), (None, "ff"))
    b.param("conv_b", (inner,), ("ff",), init="zeros")
    b.param("x_proj", (inner, dt_rank + 2 * s.state_dim), ("ff", None))
    b.param("dt_proj", (dt_rank, inner), (None, "ff"), fan_in=dt_rank)
    b.param("dt_bias", (inner,), ("ff",), init="zeros")
    b.param("a_log", (inner, s.state_dim), ("ff", "state"), init="ones")
    b.param("d_skip", (inner,), ("ff",), init="ones")
    b.param("out_proj", (inner, d_model), ("ff", "embed"), fan_in=inner)


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 history: Optional[jax.Array] = None,
                 n_valid: Optional[jax.Array] = None) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv. x: (B,S,C); w: (W,C). Returns (out, new_history).

    ``n_valid`` (B,) enables per-row history advance for masked serving
    batches: row b's real tokens occupy columns [0, n_valid[b]) and the new
    history must be the last W-1 of (history ++ valid tokens) — the default
    tail slice would absorb the padding columns.
    """
    width = w.shape[0]
    if history is None:
        history = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([history, x], axis=1)            # (B, S+W-1, C)
    out = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(width)) + b
    if n_valid is None:
        new_hist = xp[:, xp.shape[1] - (width - 1):, :]
    else:
        idx = (n_valid[:, None]
               + jnp.arange(width - 1, dtype=jnp.int32)[None, :])
        new_hist = jnp.take_along_axis(xp, idx[:, :, None], axis=1)
    return out, new_hist


def ssm_forward(
    params, x: jax.Array, s: SSMConfig, *,
    cache: Optional[SSMCache] = None,
    valid: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Optional[SSMCache]]:
    """x: (B, S, d) -> (B, S, d). cache!=None => recurrent decode continuation.

    ``valid`` (B, S) bool masks serving batches where rows carry different
    numbers of real tokens (valid-prefix layout): state updates at invalid
    columns are gated off, so each row's recurrence is bitwise what it
    would be with its tokens alone — the property the recurrent serving
    backend's exactness rests on.
    """
    B, S, d = x.shape
    inner = s.expand * d
    dt_rank = s.dt_rank or -(-d // 16)

    xz = jnp.einsum("bsd,di->bsi", x, params["in_proj"])
    x_in, z = xz[..., :inner], xz[..., inner:]
    hist = cache.conv if cache is not None else None
    n_valid = (jnp.sum(valid, axis=1).astype(jnp.int32)
               if valid is not None else None)
    x_c, new_hist = _causal_conv(x_in, params["conv_w"], params["conv_b"],
                                 hist, n_valid=n_valid)
    x_c = jax.nn.silu(x_c)

    proj = jnp.einsum("bsi,ir->bsr", x_c, params["x_proj"])
    dt_in = proj[..., :dt_rank]
    b_in = proj[..., dt_rank:dt_rank + s.state_dim]             # (B,S,n)
    c_in = proj[..., dt_rank + s.state_dim:]                    # (B,S,n)
    dt = jax.nn.softplus(jnp.einsum("bsr,ri->bsi", dt_in, params["dt_proj"])
                         + params["dt_bias"])                   # (B,S,i)
    a = -jnp.exp(params["a_log"].astype(jnp.float32))           # (i,n)

    h0 = (cache.state if cache is not None
          else jnp.zeros((B, inner, s.state_dim), jnp.float32))

    def step(h, inputs):
        if valid is None:
            dt_t, b_t, c_t, x_t = inputs                        # (B,i),(B,n),(B,n),(B,i)
            v_t = None
        else:
            dt_t, b_t, c_t, x_t, v_t = inputs
        dt_f = dt_t.astype(jnp.float32)
        da = jnp.exp(dt_f[:, :, None] * a[None])                # (B,i,n)
        dbx = (dt_f * x_t.astype(jnp.float32))[:, :, None] * b_t.astype(jnp.float32)[:, None, :]
        h_up = da * h + dbx
        if v_t is not None:
            h_up = jnp.where(v_t[:, None, None], h_up, h)
        y_t = jnp.einsum("bin,bn->bi", h_up, c_t.astype(jnp.float32))
        return h_up, y_t

    xs = (jnp.moveaxis(dt, 1, 0), jnp.moveaxis(b_in, 1, 0),
          jnp.moveaxis(c_in, 1, 0), jnp.moveaxis(x_c, 1, 0))
    if valid is not None:
        xs = xs + (jnp.moveaxis(valid, 1, 0),)
    h_last, ys = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1).astype(x.dtype)                  # (B,S,i)
    y = y + x_c * params["d_skip"]
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bsi,id->bsd", y, params["out_proj"])

    new_cache = None
    if cache is not None:
        new_cache = SSMCache(new_hist, h_last, cache.extra, cache.length + S)
    return out, new_cache


def ssm_init_cache(cfg_d_model: int, s: SSMConfig, batch: int,
                   dtype=jnp.bfloat16) -> SSMCache:
    inner = s.expand * cfg_d_model
    return SSMCache(
        conv=jnp.zeros((batch, s.conv_width - 1, inner), dtype),
        state=jnp.zeros((batch, inner, s.state_dim), jnp.float32),
        extra=None,
        length=jnp.zeros((), jnp.int32),
    )
