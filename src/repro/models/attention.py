"""Attention layers: GQA/MQA/MHA (full, sliding-window, encoder) and MLA.

All score math runs in float32; inputs/outputs stay in the compute dtype.
Decode uses the KV caches from ``kvcache.py``; MLA decode uses the *absorbed*
formulation (scores against the compressed cache — the memory-bound win that
makes MLA decode viable at 32k).
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import AttentionConfig
from repro.kernels import paged_attention as paged_kernel
from repro.models.common import ParamBuilder, rms_norm
from repro.models.kvcache import KVCache, MLACache, PagedKVCache, PagedLayout
from repro.models.rope import apply_mrope, apply_rope

NEG_INF = -2.0 ** 30  # large-but-finite: keeps fully-masked rows NaN-free


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------

def init_gqa(b: ParamBuilder, d_model: int, a: AttentionConfig) -> None:
    b.param("wq", (d_model, a.num_heads, a.head_dim), ("embed", "heads", "head_dim"))
    b.param("wk", (d_model, a.num_kv_heads, a.head_dim), ("embed", "kv_heads", "head_dim"))
    b.param("wv", (d_model, a.num_kv_heads, a.head_dim), ("embed", "kv_heads", "head_dim"))
    b.param("wo", (a.num_heads, a.head_dim, d_model), ("heads", "head_dim", "embed"),
            fan_in=a.num_heads * a.head_dim)


def init_mla(b: ParamBuilder, d_model: int, a: AttentionConfig) -> None:
    qk_head = a.qk_nope_head_dim + a.qk_rope_head_dim
    b.param("wq", (d_model, a.num_heads, qk_head), ("embed", "heads", "head_dim"))
    b.param("w_dkv", (d_model, a.kv_lora_rank + a.qk_rope_head_dim), ("embed", "kv_lora"))
    b.param("kv_norm", (a.kv_lora_rank,), ("kv_lora",), init="zeros")
    b.param("w_uk", (a.kv_lora_rank, a.num_heads, a.qk_nope_head_dim),
            ("kv_lora", "heads", "head_dim"))
    b.param("w_uv", (a.kv_lora_rank, a.num_heads, a.v_head_dim),
            ("kv_lora", "heads", "head_dim"))
    b.param("wo", (a.num_heads, a.v_head_dim, d_model), ("heads", "head_dim", "embed"),
            fan_in=a.num_heads * a.v_head_dim)


# ---------------------------------------------------------------------------
# Masking
# ---------------------------------------------------------------------------

def make_mask(q_len: int, kv_len: int, *, causal: bool,
              window: Optional[int] = None,
              q_offset: Optional[jax.Array] = None) -> jax.Array:
    """(q_len, kv_len) boolean mask. ``q_offset``: absolute position of q[0]."""
    q_pos = jnp.arange(q_len)
    if q_offset is not None:
        q_pos = q_pos + q_offset
    k_pos = jnp.arange(kv_len)
    rel = q_pos[:, None] - k_pos[None, :]
    mask = jnp.ones((q_len, kv_len), bool)
    if causal:
        mask &= rel >= 0
    if window is not None:
        mask &= rel < window
    return mask


def _sdpa(q: jax.Array, k: jax.Array, v: jax.Array, mask: Optional[jax.Array],
          scale: float) -> jax.Array:
    """q: (B,S,K,G,D) grouped; k,v: (B,T,K,D). Returns (B,S,K,G,D_v)."""
    scores = jnp.einsum("bskgd,btkd->bkgst", q, k, preferred_element_type=jnp.float32)
    scores = scores * scale
    if mask is not None:
        scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", probs.astype(v.dtype), v)
    return out


# Chunking policy: sequences whose (S x T) score matrix would exceed this many
# elements per (batch, head) take the blockwise online-softmax path. This is
# the pure-jnp flash-attention formulation (also the oracle for the Pallas
# kernel in kernels/flash_attention).
CHUNK_THRESHOLD = 1 << 22
Q_CHUNK = 1024
KV_CHUNK = 1024
# Beyond-paper opt (§Perf): skip kv blocks that are fully masked for a q block
# (causal upper triangle / outside the sliding window). Python-unrolled over q
# blocks, so HLO grows O(n_q_blocks); enabled per-run by the perf configs.
BLOCK_SKIP = False


def _use_chunked(s: int, t: int) -> bool:
    return s > 1 and s * t > CHUNK_THRESHOLD


def _chunk_of(n: int, want: int) -> int:
    c = min(want, n)
    while n % c:
        c -= 1
    return c


def _sdpa_chunked(q: jax.Array, k: jax.Array, v: jax.Array, *, scale: float,
                  q_pos: jax.Array, kv_pos: jax.Array, causal: bool,
                  window: Optional[int], canonical_positions: bool = False,
                  q_chunk: int = 0, kv_chunk: int = 0) -> jax.Array:
    """Blockwise online-softmax attention (flash formulation, pure jnp).

    q: (B,S,K,G,D); k,v: (B,T,K,Dk/Dv); q_pos: (B,S); kv_pos: (B,T).
    Peak memory is O(q_chunk x kv_chunk) scores per (B,K,G) instead of SxT.
    The kv loop is a ``lax.scan`` with a checkpointed body, so the backward
    pass recomputes block scores (flash-bwd) instead of storing them.
    """
    B, S, K, G, D = q.shape
    T = k.shape[1]
    Dv = v.shape[-1]
    q_pos = jnp.broadcast_to(q_pos, (B, S))
    kv_pos = jnp.broadcast_to(kv_pos, (B, T))
    qc = _chunk_of(S, q_chunk or Q_CHUNK)
    kc = _chunk_of(T, kv_chunk or KV_CHUNK)
    nq, nk = S // qc, T // kc

    q_r = jnp.moveaxis(q.reshape(B, nq, qc, K, G, D), 1, 0)
    qp_r = jnp.moveaxis(q_pos.reshape(B, nq, qc), 1, 0)
    k_r = jnp.moveaxis(k.reshape(B, nk, kc, K, D), 1, 0)
    v_r = jnp.moveaxis(v.reshape(B, nk, kc, K, Dv), 1, 0)
    kp_r = jnp.moveaxis(kv_pos.reshape(B, nk, kc), 1, 0)

    def block(qb, qpb, kb, vb, kpb, carry):
        m, l, acc = carry
        s = jnp.einsum("bqkgd,btkd->bkgqt", qb, kb,
                       preferred_element_type=jnp.float32) * scale
        rel = (qpb[:, None, None, :, None].astype(jnp.int32)
               - kpb[:, None, None, None, :].astype(jnp.int32))
        mask = jnp.ones(rel.shape, bool)
        if causal:
            mask &= rel >= 0
        if window is not None:
            mask &= rel < window
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(-1)
        acc_new = (acc * alpha[..., None]
                   + jnp.einsum("bkgqt,btkd->bkgqd", p.astype(vb.dtype), vb)
                   .astype(jnp.float32))
        return m_new, l_new, acc_new

    block_ck = jax.checkpoint(block)

    def init_carry():
        return (jnp.full((B, K, G, qc), -1e30, jnp.float32),
                jnp.zeros((B, K, G, qc), jnp.float32),
                jnp.zeros((B, K, G, qc, Dv), jnp.float32))

    def finish(carry):
        m, l, acc = carry
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return jnp.moveaxis(out, 3, 1)           # (B,qc,K,G,Dv)

    if BLOCK_SKIP and canonical_positions:
        # Positions are the canonical arange from 0, so each (q block, kv
        # block) pair's visibility is static: skip kv blocks entirely above
        # the causal diagonal or entirely outside the sliding window. This
        # removes the ~2x causal waste (and ~T/window for local layers) from
        # the compute roofline term at the cost of O(nq) HLO body clones.
        outs = []
        for i in range(nq):
            lo, hi = i * qc, i * qc + qc - 1     # absolute q range
            carry = init_carry()
            for j in range(nk):
                k_lo, k_hi = j * kc, j * kc + kc - 1
                if causal and k_lo > hi:
                    continue                      # above the diagonal
                if window is not None and k_hi < lo - window + 1:
                    continue                      # before the window
                carry = block_ck(q_r[i], qp_r[i], k_r[j], v_r[j], kp_r[j],
                                 carry)
            outs.append(finish(carry))
        out = jnp.concatenate(outs, axis=1)       # (B,S,K,G,Dv)
        return out.astype(q.dtype)

    def per_q(args):
        qb, qpb = args

        def body(carry, inp):
            kb, vb, kpb = inp
            return block_ck(qb, qpb, kb, vb, kpb, carry), None

        carry, _ = jax.lax.scan(body, init_carry(), (k_r, v_r, kp_r))
        return finish(carry)

    out = jax.lax.map(per_q, (q_r, qp_r))        # (nq,B,qc,K,G,Dv)
    out = jnp.moveaxis(out, 0, 1).reshape(B, S, K, G, Dv)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA forward
# ---------------------------------------------------------------------------

def gqa_attention(
    params,
    x: jax.Array,                          # (B, S, d)
    a: AttentionConfig,
    *,
    causal: bool = True,
    window: Optional[int] = None,          # sliding window (None = full)
    cache: Optional[KVCache] = None,       # decode/prefill-with-cache
    positions: Optional[jax.Array] = None, # (B, S) absolute positions
    mrope_positions: Optional[jax.Array] = None,  # (3, B, S)
) -> Tuple[jax.Array, Optional[KVCache]]:
    B, S, _ = x.shape
    H, K, D = a.num_heads, a.num_kv_heads, a.head_dim
    G = H // K
    if positions is None:
        offset = cache.length if cache is not None else jnp.int32(0)
        positions = jnp.arange(S, dtype=jnp.int32)[None, :] + offset

    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])        # (B,S,H,D)
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])        # (B,S,K,D)
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])

    if a.mrope:
        mpos = mrope_positions
        if mpos is None:
            mpos = jnp.broadcast_to(positions[None], (3, B, S))
        q = apply_mrope(q, mpos, a.rope_theta, a.mrope_sections)
        k = apply_mrope(k, mpos, a.rope_theta, a.mrope_sections)
    elif a.rotary_pct > 0:
        q = apply_rope(q, positions, a.rope_theta, a.rotary_pct)
        k = apply_rope(k, positions, a.rope_theta, a.rotary_pct)

    qg = q.reshape(B, S, K, G, D)
    scale = 1.0 / math.sqrt(D)

    new_cache = None
    if cache is not None and S > 1:
        # Prefill into a fresh cache: attend over the in-block k/v (identical
        # result, avoids touching max_len empty slots), then append. Same
        # chunking policy as the no-cache branch — short prefills use the
        # plain softmax so cached and cacheless forward stay bitwise
        # consistent (greedy serving depends on that identity).
        new_cache = cache.append(k, v)
        if _use_chunked(S, S):
            out = _sdpa_chunked(qg, k.astype(x.dtype), v.astype(x.dtype),
                                scale=scale, q_pos=positions,
                                kv_pos=positions, causal=causal,
                                window=window, canonical_positions=True)
        else:
            mask = None
            if causal or window is not None:
                mask = make_mask(S, S, causal=causal,
                                 window=window)[None, None, None]
            out = _sdpa(qg, k.astype(x.dtype), v.astype(x.dtype), mask,
                        scale)
    elif cache is not None:
        # Decode: dense scores over the cache (S==1: scores are (B,K,G,1,T)).
        new_cache = cache.append(k, v)
        k_all, v_all = new_cache.k, new_cache.v
        T = new_cache.max_len
        kv_pos = jnp.arange(T)
        rel = positions[:, :, None] - kv_pos[None, None, :]    # (B,S,T)
        mask = rel >= 0
        if window is not None:
            mask &= rel < window
        mask = mask[:, None, None, :, :]                       # (B,1,1,S,T)
        out = _sdpa(qg, k_all.astype(x.dtype), v_all.astype(x.dtype), mask,
                    scale)
    elif _use_chunked(S, S):
        out = _sdpa_chunked(qg, k.astype(x.dtype), v.astype(x.dtype),
                            scale=scale, q_pos=positions,
                            kv_pos=positions, causal=causal, window=window,
                            canonical_positions=True)
    else:
        mask = None
        if causal or window is not None:
            mask = make_mask(S, S, causal=causal, window=window)[None, None, None]
        out = _sdpa(qg, k.astype(x.dtype), v.astype(x.dtype), mask, scale)

    out = out.reshape(B, S, H, D)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return y, new_cache


# ---------------------------------------------------------------------------
# Paged GQA forward (serving: block-table cache, decode + chunked prefill)
# ---------------------------------------------------------------------------

def gqa_paged_attention(
    params,
    x: jax.Array,                          # (B, C, d): C-token chunk per slot
    a: AttentionConfig,
    *,
    cache: PagedKVCache,
    layout: PagedLayout,
    window: Optional[int] = None,
    kernel="auto",
) -> Tuple[jax.Array, PagedKVCache]:
    """One serving step through a paged cache.

    Each batch row is one request slot advancing ``n_valid`` tokens whose
    absolute positions start at ``starts`` — decode rows advance 1 token,
    chunked-prefill rows up to C, idle rows 0. New k/v scatter into the
    shared pool through the block table; scores then run either through the
    stash-resident Pallas kernel (``kernel="pallas"`` — live blocks stream
    pool->VMEM, the logical view never exists in HBM) or the gather-then-
    dense oracle (``kernel="ref"``). ``"auto"`` picks pallas wherever TPU
    semantics are available (``kernels.paged_attention.resolve_kernel``).
    ``kernel`` may also be a *callable* with ``paged_attention_ref``'s
    signature — that is how ``runtime.steps`` threads the shard_map'd
    multi-device lowering (``make_sharded_paged_attention``) down here
    without the model layer knowing about meshes.
    Columns beyond ``n_valid`` produce garbage outputs that the caller
    discards (their cache writes are dropped), which is what lets decode and
    prefill share one compiled shape — the ISSUE-2 "decode-shaped step, no
    per-bucket prefill jits".
    """
    assert not a.mrope, "paged serving does not support mrope archs yet"
    B, C, _ = x.shape
    H, K, D = a.num_heads, a.num_kv_heads, a.head_dim
    positions = layout.token_positions(C)                   # (B, C)

    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    if a.rotary_pct > 0:
        q = apply_rope(q, positions, a.rope_theta, a.rotary_pct)
        k = apply_rope(k, positions, a.rope_theta, a.rotary_pct)

    new_cache = cache.write(k, v, layout)
    if callable(kernel):
        fn = kernel
    else:
        kind = paged_kernel.resolve_kernel(kernel)
        fn = (paged_kernel.paged_attention if kind == "pallas"
              else paged_kernel.paged_attention_ref)
    out = fn(q.astype(x.dtype), new_cache.k_pool, new_cache.v_pool,
             layout.block_tables, layout.starts, layout.n_valid,
             block_size=layout.block_size, window=window,
             scale=1.0 / math.sqrt(D))
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return y, new_cache


# ---------------------------------------------------------------------------
# MLA forward (deepseek-v2)
# ---------------------------------------------------------------------------

def mla_attention(
    params,
    x: jax.Array,
    a: AttentionConfig,
    *,
    causal: bool = True,
    cache: Optional[MLACache] = None,
    positions: Optional[jax.Array] = None,
    norm_eps: float = 1e-6,
) -> Tuple[jax.Array, Optional[MLACache]]:
    B, S, _ = x.shape
    H = a.num_heads
    dn, dr, dv, r = a.qk_nope_head_dim, a.qk_rope_head_dim, a.v_head_dim, a.kv_lora_rank
    if positions is None:
        offset = cache.length if cache is not None else jnp.int32(0)
        positions = jnp.arange(S, dtype=jnp.int32)[None, :] + offset

    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])         # (B,S,H,dn+dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, a.rope_theta)

    ckr = jnp.einsum("bsd,dr->bsr", x, params["w_dkv"])      # (B,S,r+dr)
    c_kv = rms_norm(ckr[..., :r], params["kv_norm"], norm_eps)
    k_rope = apply_rope(ckr[..., None, r:], positions, a.rope_theta)[:, :, 0]  # (B,S,dr)

    scale = 1.0 / math.sqrt(dn + dr)
    new_cache = None
    if cache is not None and S == 1:
        # Absorbed decode: score against the compressed cache directly —
        # the memory-bound win that makes MLA decode viable at 32k.
        new_cache = cache.append(c_kv, k_rope)
        c_all, kr_all = new_cache.c_kv.astype(x.dtype), new_cache.k_rope.astype(x.dtype)
        T = new_cache.max_len
        rel = positions[:, :, None] - jnp.arange(T)[None, None, :]
        mask = (rel >= 0)[:, None, :, :]                     # (B,1,S,T)
        # absorbed scores: q_nope (B,S,H,dn) @ w_uk -> (B,S,H,r) then vs c_kv
        q_abs = jnp.einsum("bshn,rhn->bshr", q_nope, params["w_uk"])
        scores = jnp.einsum("bshr,btr->bhst", q_abs, c_all,
                            preferred_element_type=jnp.float32)
        scores += jnp.einsum("bshr,btr->bhst", q_rope, kr_all,
                             preferred_element_type=jnp.float32)
        probs = jax.nn.softmax(jnp.where(mask, scores * scale, NEG_INF)
                               .astype(jnp.float32), axis=-1)
        ctx = jnp.einsum("bhst,btr->bshr", probs.astype(x.dtype), c_all)
        out = jnp.einsum("bshr,rhv->bshv", ctx, params["w_uv"])
    else:
        # Train, or prefill into a fresh cache: full-rank in-block attention.
        if cache is not None:
            new_cache = cache.append(c_kv, k_rope)
        k_nope = jnp.einsum("bsr,rhn->bshn", c_kv, params["w_uk"])
        v = jnp.einsum("bsr,rhv->bshv", c_kv, params["w_uv"])
        if _use_chunked(S, S):
            # concat trick: [q_nope|q_rope] . [k_nope|k_rope] in one product
            q_cat = jnp.concatenate(
                [q_nope, q_rope], axis=-1)[:, :, :, None, :]  # (B,S,K=H,G=1,dn+dr)
            k_cat = jnp.concatenate(
                [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                          (B, S, H, dr))], axis=-1)
            out = _sdpa_chunked(q_cat, k_cat, v, scale=scale,
                                q_pos=positions, kv_pos=positions,
                                causal=causal, window=None,
                                canonical_positions=True)[:, :, :, 0, :]
        else:
            scores = jnp.einsum("bshn,bthn->bhst", q_nope, k_nope,
                                preferred_element_type=jnp.float32)
            scores += jnp.einsum("bshr,btr->bhst", q_rope, k_rope,
                                 preferred_element_type=jnp.float32)
            if causal:
                mask = make_mask(S, S, causal=True)[None, None]
                scores = jnp.where(mask, scores * scale, NEG_INF)
            else:
                scores = scores * scale
            probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1)
            out = jnp.einsum("bhst,bthv->bshv", probs.astype(x.dtype), v)

    y = jnp.einsum("bshv,hvd->bsd", out, params["wo"])
    return y, new_cache
