"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory, recurrent).

Both use exponential gating with the max-stabilizer from the xLSTM paper
(arXiv:2405.04517). q/k/v and the sLSTM recurrence use block-diagonal
per-head projections. Sequential ``lax.scan`` is the reference path; the
chunked-parallel mLSTM (linear-attention form) is the Pallas kernel target.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import XLSTMConfig
from repro.models.common import ParamBuilder
from repro.models.kvcache import SSMCache


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def init_mlstm(b: ParamBuilder, d_model: int, x: XLSTMConfig) -> None:
    inner = int(d_model * x.proj_factor_mlstm)
    h = x.num_heads
    dh = inner // h
    b.param("up_proj", (d_model, 2 * inner), ("embed", "ff"))
    b.param("conv_w", (x.conv_width, inner), (None, "ff"))
    b.param("conv_b", (inner,), ("ff",), init="zeros")
    for n in ("wq", "wk", "wv"):
        b.param(n, (h, dh, dh), ("heads", "head_dim", "head_dim"), fan_in=dh)
    b.param("w_gates", (inner, 2 * h), ("ff", None))   # i~, f~ per head
    b.param("b_gates", (2 * h,), (None,), init="zeros")
    b.param("out_norm", (inner,), ("ff",), init="zeros")
    b.param("down_proj", (inner, d_model), ("ff", "embed"), fan_in=inner)


def _mlstm_scan(q, k, v, i_raw, f_raw, state=None, valid=None):
    """Stabilized mLSTM recurrence.

    q,k,v: (B,S,H,dh); i_raw,f_raw: (B,S,H). Returns (y (B,S,H,dh), state).
    state = (C (B,H,dh,dh), n (B,H,dh), m (B,H)) all float32.

    ``valid`` (B,S) bool gates the state update per row/step (masked
    serving batches): invalid columns leave (C, n, m) untouched so each
    row advances by exactly its own tokens.
    """
    B, S, H, dh = q.shape
    if state is None:
        c0 = jnp.zeros((B, H, dh, dh), jnp.float32)
        n0 = jnp.zeros((B, H, dh), jnp.float32)
        m0 = jnp.full((B, H), -jnp.inf, jnp.float32)
    else:
        c0, n0, m0 = state

    def step(carry, inp):
        c, n, m = carry
        if valid is None:
            q_t, k_t, v_t, i_t, f_t = inp                   # (B,H,dh)x3,(B,H)x2
            v_col = None
        else:
            q_t, k_t, v_t, i_t, f_t, v_col = inp
        f_log = jax.nn.log_sigmoid(f_t.astype(jnp.float32))
        i_log = i_t.astype(jnp.float32)
        m_new = jnp.maximum(f_log + m, i_log)
        i_p = jnp.exp(i_log - m_new)                        # (B,H)
        f_p = jnp.exp(f_log + m - m_new)
        kf = k_t.astype(jnp.float32) * (dh ** -0.5)
        vf = v_t.astype(jnp.float32)
        c_up = f_p[..., None, None] * c + i_p[..., None, None] * (
            kf[..., :, None] * vf[..., None, :])            # (B,H,dh,dh)
        n_up = f_p[..., None] * n + i_p[..., None] * kf
        qf = q_t.astype(jnp.float32)
        num = jnp.einsum("bhde,bhd->bhe", c_up, qf)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n_up, qf)),
                          jnp.exp(-m_new))[..., None]
        y_t = num / den
        if v_col is not None:
            c_up = jnp.where(v_col[:, None, None, None], c_up, c)
            n_up = jnp.where(v_col[:, None, None], n_up, n)
            m_new = jnp.where(v_col[:, None], m_new, m)
        return (c_up, n_up, m_new), y_t

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (q, k, v, i_raw, f_raw))
    if valid is not None:
        xs = xs + (jnp.moveaxis(valid, 1, 0),)
    (c, n, m), ys = jax.lax.scan(step, (c0, n0, m0), xs)
    return jnp.moveaxis(ys, 0, 1), (c, n, m)


def _mlstm_chunked(q, k, v, i_raw, f_raw, state=None, chunk: int = 256):
    """Chunk-parallel mLSTM — exact-math reformulation of ``_mlstm_scan``.

    The sequential recurrence unrolls to (with F_t = Σ_{u<=t} log σ(f_u),
    g_k = i_k - F_k, M*_j = max(m_in, cummax_{k<=j} g_k), m_j = F_j + M*_j):

        C_stab_j = Σ_{k<=j} e^{g_k - M*_j} k̂_k v_kᵀ + e^{m_in - M*_j} C_in
        y_j      = q_j·C_stab_j / max(|q_j·n_stab_j|, e^{-m_j})

    so a chunk of ck steps is two MXU einsums (an intra-chunk masked
    attention and one cross-chunk state contraction) instead of ck
    elementwise (dh x dh) outer-product updates — the §Perf B1 change:
    state trajectories are only materialized at chunk boundaries
    (S/ck boundaries instead of S), and the O(S·dh²) work runs on the MXU.
    Mathematically identical to the scan; numerically equal to ~1e-4
    (different-but-valid stabilizer grouping). Validated vs the scan oracle
    in tests/test_xlstm_chunked.py.
    """
    B, S, H, dh = q.shape
    ck = min(chunk, S)
    while S % ck:
        ck -= 1
    nc = S // ck

    f_log = jax.nn.log_sigmoid(f_raw.astype(jnp.float32))      # (B,S,H)
    i_log = i_raw.astype(jnp.float32)
    kf = k.astype(jnp.float32) * (dh ** -0.5)
    qf = q.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    def to_chunks(t):
        return jnp.moveaxis(t.reshape(B, nc, ck, *t.shape[2:]), 1, 0)

    q_c, k_c, v_c = to_chunks(qf), to_chunks(kf), to_chunks(vf)
    f_c, i_c = to_chunks(f_log), to_chunks(i_log)

    if state is None:
        c0 = jnp.zeros((B, H, dh, dh), jnp.float32)
        n0 = jnp.zeros((B, H, dh), jnp.float32)
        m0 = jnp.full((B, H), -jnp.inf, jnp.float32)
    else:
        c0, n0, m0 = state

    causal = jnp.tril(jnp.ones((ck, ck), bool))                 # k<=j

    def chunk_step(carry, inp):
        c_in, n_in, m_in = carry
        qb, kb, vb, fb, ib = inp                                # (B,ck,H,*)
        F = jnp.cumsum(fb, axis=1)                              # (B,ck,H)
        g = ib - F
        mstar = jnp.maximum(jax.lax.cummax(g, axis=1),
                            m_in[:, None, :])                   # (B,ck,H)
        m = F + mstar

        # intra-chunk: masked attention with decay weights
        scores = jnp.einsum("bjhd,bkhd->bhjk", qb, kb)          # (B,H,ck,ck)
        logw = (g[:, None, :, :].transpose(0, 3, 1, 2)          # g_k: (B,H,1,ck)
                - mstar.transpose(0, 2, 1)[:, :, :, None])      # -M*_j
        w = jnp.where(causal[None, None], jnp.exp(logw), 0.0)
        num = jnp.einsum("bhjk,bkhd->bjhd", scores * w, vb)
        n_intra = jnp.einsum("bhjk,bkhd->bjhd", w, kb)

        # cross-chunk: carried state contribution
        carry_w = jnp.exp(m_in[:, None, :] - mstar)             # (B,ck,H)
        num = num + jnp.einsum("bjhd,bhde->bjhe", qb, c_in) * carry_w[..., None]
        n_all = n_intra + n_in[:, None, :, :] * carry_w[..., None]

        qn = jnp.einsum("bjhd,bjhd->bjh", qb, n_all)
        den = jnp.maximum(jnp.abs(qn), jnp.exp(-m))
        y = num / den[..., None]                                # (B,ck,H,dh)

        # state carry to the next chunk (coefficients at j = ck)
        F_tot = F[:, -1, :]                                     # (B,H)
        ms_tot = mstar[:, -1, :]
        kv_w = jnp.exp(g - ms_tot[:, None, :])                  # (B,ck,H)
        c_out = (jnp.einsum("bkhd,bkhe,bkh->bhde", kb, vb, kv_w)
                 + c_in * jnp.exp(m_in - ms_tot)[:, :, None, None])
        n_out = (jnp.einsum("bkhd,bkh->bhd", kb, kv_w)
                 + n_in * jnp.exp(m_in - ms_tot)[:, :, None])
        m_out = F_tot + ms_tot
        return (c_out, n_out, m_out), y

    (c, n, m), ys = jax.lax.scan(chunk_step, (c0, n0, m0),
                                 (q_c, k_c, v_c, f_c, i_c))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, H, dh)
    return y, (c, n, m)


def _group_norm_heads(y: jax.Array, scale: jax.Array, heads: int) -> jax.Array:
    """Per-head RMS norm of (B,S,inner) reshaped to heads."""
    B, S, inner = y.shape
    yh = y.reshape(B, S, heads, inner // heads).astype(jnp.float32)
    var = jnp.mean(jnp.square(yh), axis=-1, keepdims=True)
    yh = yh * jax.lax.rsqrt(var + 1e-6)
    return (yh.reshape(B, S, inner) * (1.0 + scale.astype(jnp.float32)))


def mlstm_forward(params, x: jax.Array, xc: XLSTMConfig, *,
                  cache: Optional[SSMCache] = None,
                  valid: Optional[jax.Array] = None
                  ) -> Tuple[jax.Array, Optional[SSMCache]]:
    B, S, d = x.shape
    inner = int(d * xc.proj_factor_mlstm)
    h = xc.num_heads
    dh = inner // h

    up = jnp.einsum("bsd,di->bsi", x, params["up_proj"])
    x_in, z = up[..., :inner], up[..., inner:]
    hist = cache.conv if cache is not None else jnp.zeros(
        (B, xc.conv_width - 1, inner), x.dtype)
    xp = jnp.concatenate([hist, x_in], axis=1)
    x_c = sum(xp[:, i:i + S, :] * params["conv_w"][i]
              for i in range(xc.conv_width)) + params["conv_b"]
    x_c = jax.nn.silu(x_c)
    if valid is None:
        new_hist = xp[:, xp.shape[1] - (xc.conv_width - 1):, :]
    else:
        # per-row history: last W-1 of (history ++ valid tokens); the tail
        # slice would absorb this step's padding columns
        n_val = jnp.sum(valid, axis=1).astype(jnp.int32)
        idx = (n_val[:, None]
               + jnp.arange(xc.conv_width - 1, dtype=jnp.int32)[None, :])
        new_hist = jnp.take_along_axis(xp, idx[:, :, None], axis=1)

    xh = x_c.reshape(B, S, h, dh)
    q = jnp.einsum("bshd,hde->bshe", xh, params["wq"])
    k = jnp.einsum("bshd,hde->bshe", xh, params["wk"])
    v = jnp.einsum("bshd,hde->bshe", x_in.reshape(B, S, h, dh), params["wv"])
    gates = jnp.einsum("bsi,ig->bsg", x_c, params["w_gates"]) + params["b_gates"]
    i_raw, f_raw = gates[..., :h], gates[..., h:]

    state = None
    if cache is not None:
        c_prev = cache.state
        n_prev, m_prev = cache.extra
        state = (c_prev, n_prev, m_prev)
    if valid is None and S >= 2 * xc.chunk:
        # chunk-parallel form (§Perf B1): MXU einsums + O(S/chunk) state
        # materialization instead of an O(S) elementwise recurrence; masked
        # batches stay on the scan — per-step gating has no chunked form
        y, new_state = _mlstm_chunked(q, k, v, i_raw, f_raw, state,
                                      chunk=xc.chunk)
    else:
        y, new_state = _mlstm_scan(q, k, v, i_raw, f_raw, state, valid=valid)

    y = _group_norm_heads(y.reshape(B, S, inner), params["out_norm"], h)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = jnp.einsum("bsi,id->bsd", y, params["down_proj"])

    new_cache = None
    if cache is not None:
        c, n, m = new_state
        new_cache = SSMCache(new_hist, c, (n, m), cache.length + S)
    return out, new_cache


def mlstm_init_cache(d_model: int, xc: XLSTMConfig, batch: int,
                     dtype=jnp.bfloat16) -> SSMCache:
    inner = int(d_model * xc.proj_factor_mlstm)
    h, dh = xc.num_heads, inner // xc.num_heads
    return SSMCache(
        conv=jnp.zeros((batch, xc.conv_width - 1, inner), dtype),
        state=jnp.zeros((batch, h, dh, dh), jnp.float32),
        extra=(jnp.zeros((batch, h, dh), jnp.float32),
               jnp.full((batch, h), -jnp.inf, jnp.float32)),
        length=jnp.zeros((), jnp.int32),
    )


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_ff_half(d_model: int, x: XLSTMConfig) -> int:
    """Gated-FF half width: proj_factor * d_model rounded up to 64 (TPU lane
    alignment; also keeps the 2-way gate split exact for any d_model)."""
    return -(-int(d_model * x.proj_factor_slstm) // 64) * 64


def init_slstm(b: ParamBuilder, d_model: int, x: XLSTMConfig) -> None:
    h = x.num_heads
    dh = d_model // h
    b.param("w_in", (d_model, 4 * d_model), ("embed", "ff"))
    b.param("r_rec", (h, dh, 4 * dh), ("heads", "head_dim", None), fan_in=dh)
    b.param("b_in", (4 * d_model,), (None,), init="zeros")
    b.param("out_norm", (d_model,), ("embed",), init="zeros")
    half = slstm_ff_half(d_model, x)
    b.param("ff_up", (d_model, 2 * half), ("embed", "ff"))
    b.param("ff_down", (half, d_model), ("ff", "embed"), fan_in=half)


def slstm_forward(params, x: jax.Array, xc: XLSTMConfig, *,
                  cache: Optional[SSMCache] = None,
                  valid: Optional[jax.Array] = None
                  ) -> Tuple[jax.Array, Optional[SSMCache]]:
    B, S, d = x.shape
    h = xc.num_heads
    dh = d // h

    w = jnp.einsum("bsd,dg->bsg", x, params["w_in"]) + params["b_in"]  # (B,S,4d)

    if cache is not None:
        h0 = cache.state                                    # (B,d)
        c0, n0, m0 = cache.extra                            # (B,d),(B,d),(B,h)
    else:
        h0 = jnp.zeros((B, d), jnp.float32)
        c0 = jnp.zeros((B, d), jnp.float32)
        n0 = jnp.ones((B, d), jnp.float32)
        m0 = jnp.zeros((B, h), jnp.float32)

    r_rec = params["r_rec"].astype(jnp.float32)

    def step(carry, inp):
        h_prev, c, n, m = carry                             # (B,d) f32
        if valid is None:
            w_t = inp
            v_col = None
        else:
            w_t, v_col = inp
        hh = h_prev.reshape(B, h, dh)
        rec = jnp.einsum("bhd,hdg->bhg", hh, r_rec).reshape(B, 4 * d)
        raw = w_t.astype(jnp.float32) + rec
        i_r, f_r, z_r, o_r = jnp.split(raw, 4, axis=-1)     # (B,d) each
        # per-head stabilizer (max over head dims of the gate pre-acts)
        i_h = i_r.reshape(B, h, dh)
        f_h = jax.nn.log_sigmoid(f_r).reshape(B, h, dh)
        m_new = jnp.maximum(jnp.max(f_h, -1) + m, jnp.max(i_h, -1))  # (B,h)
        i_p = jnp.exp(i_h - m_new[..., None]).reshape(B, d)
        f_p = jnp.exp(f_h + (m - m_new)[..., None]).reshape(B, d)
        c_up = f_p * c + i_p * jnp.tanh(z_r)
        n_up = f_p * n + i_p
        h_new = jax.nn.sigmoid(o_r) * c_up / jnp.maximum(n_up, 1e-6)
        if v_col is not None:
            h_out = jnp.where(v_col[:, None], h_new, h_prev)
            c_up = jnp.where(v_col[:, None], c_up, c)
            n_up = jnp.where(v_col[:, None], n_up, n)
            m_new = jnp.where(v_col[:, None], m_new, m)
            return (h_out, c_up, n_up, m_new), h_new
        return (h_new, c_up, n_up, m_new), h_new

    xs = (jnp.moveaxis(w, 1, 0) if valid is None
          else (jnp.moveaxis(w, 1, 0), jnp.moveaxis(valid, 1, 0)))
    (h_last, c, n, m), ys = jax.lax.scan(step, (h0, c0, n0, m0), xs)
    y = jnp.moveaxis(ys, 0, 1)                              # (B,S,d) f32
    var = jnp.mean(jnp.square(y.reshape(B, S, h, dh)), -1, keepdims=True)
    y = (y.reshape(B, S, h, dh) * jax.lax.rsqrt(var + 1e-6)).reshape(B, S, d)
    y = (y * (1.0 + params["out_norm"].astype(jnp.float32))).astype(x.dtype)
    # gated FF (proj_factor 4/3, GeLU)
    up = jnp.einsum("bsd,df->bsf", y, params["ff_up"])
    u, g = jnp.split(up, 2, axis=-1)
    out = jnp.einsum("bsf,fd->bsd", u * jax.nn.gelu(g), params["ff_down"])

    new_cache = None
    if cache is not None:
        new_cache = SSMCache(cache.conv, h_last, (c, n, m), cache.length + S)
    return out, new_cache


def slstm_init_cache(d_model: int, xc: XLSTMConfig, batch: int,
                     dtype=jnp.bfloat16) -> SSMCache:
    h = xc.num_heads
    return SSMCache(
        conv=jnp.zeros((batch, 0, 0), dtype),
        state=jnp.zeros((batch, d_model), jnp.float32),
        extra=(jnp.zeros((batch, d_model), jnp.float32),
               jnp.ones((batch, d_model), jnp.float32),
               jnp.zeros((batch, h), jnp.float32)),
        length=jnp.zeros((), jnp.int32),
    )
