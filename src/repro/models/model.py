"""The LM model: layer plan -> scanned repeated-pattern groups -> logits.

One model definition covers all 10 assigned architectures. Layers are grouped
into (pattern, repeats) *super-blocks*; parameters for each pattern position
are stacked over repeats and executed under ``lax.scan`` — this keeps the HLO
size O(#distinct block types) instead of O(#layers), which is what makes the
72B/80L dry-run compile quickly, and naturally expresses mixed stacks
(gemma3's 5:1 local:global, xLSTM's 7:1 mLSTM:sLSTM) with zero parameter
waste.

Entry points:
  init_params(cfg, key)               -> (params, logical_axes)
  forward(cfg, params, tokens, ...)   -> (logits, new_cache, aux)
  init_cache(cfg, batch, max_len)     -> cache pytree
  loss_fn(cfg, params, batch)         -> (loss, metrics)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import blocks as blocks_mod
from repro.models.common import ParamBuilder, rms_norm, softcap, stack_axes
from repro.models.kvcache import PagedLayout, RecurrentLayout

PyTree = Any


# ---------------------------------------------------------------------------
# Layer plan
# ---------------------------------------------------------------------------

def layer_plan(cfg: ModelConfig) -> List[Tuple[Tuple[str, ...], int]]:
    """[(pattern, repeats), ...] covering cfg.num_layers in order."""
    L = cfg.num_layers
    if cfg.xlstm is not None:
        k = cfg.xlstm.slstm_every
        if k and L >= k:
            pattern = ("mlstm",) * (k - 1) + ("slstm",)
            groups = [(pattern, L // k)]
            if L % k:
                groups.append((("mlstm",) * (L % k), 1))
            return groups
        return [(("mlstm",), L)]

    if cfg.ssm is not None and cfg.attention is None:
        # pure selective-SSM stack (mamba): every layer is the same block
        return [(("ssm",), L)]

    a = cfg.attention
    if cfg.family == "moe":
        first = cfg.moe.first_dense_layers
        if a.kind == "mla":
            dense_bt, moe_bt = "mla_dense", "mla_moe"
        else:
            dense_bt, moe_bt = "attn_full", "attn_moe"
        groups = []
        if first:
            groups.append(((dense_bt,), first))
        groups.append(((moe_bt,), L - first))
        return groups

    if cfg.parallel_ssm_attn:
        ratio = a.local_global_ratio
        if ratio:
            cyc = ("hybrid_local",) * ratio + ("hybrid_full",)
            n = L // len(cyc)
            groups = [(cyc, n)]
            rem = L - n * len(cyc)
            if rem:
                groups.append((("hybrid_local",) * rem, 1))
            return groups
        return [(("hybrid_full",), L)]

    if a is not None and a.local_global_ratio:
        cyc = ("attn_local",) * a.local_global_ratio + ("attn_full",)
        n = L // len(cyc)
        groups = [(cyc, n)]
        rem = L - n * len(cyc)
        if rem:
            groups.append((("attn_local",) * rem, 1))
        return groups

    return [(("attn_full",), L)]


def flat_block_types(cfg: ModelConfig) -> List[str]:
    out: List[str] = []
    for pattern, r in layer_plan(cfg):
        out.extend(list(pattern) * r)
    return out


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, key: jax.Array,
                param_dtype=jnp.float32) -> Tuple[PyTree, PyTree]:
    b = ParamBuilder(key, dtype=param_dtype)
    b.param("embed", (cfg.vocab_size, cfg.d_model), ("vocab", "embed"))
    if cfg.frontend.kind != "none" and cfg.frontend.feature_dim != cfg.d_model:
        b.param("frontend_proj", (cfg.frontend.feature_dim, cfg.d_model),
                (None, "embed"))
    if not cfg.tie_embeddings:
        b.param("head", (cfg.d_model, cfg.vocab_size), ("embed", "vocab"))
    b.param("final_norm", (cfg.d_model,), ("embed",), init="zeros")

    groups_p: List[Any] = []
    groups_a: List[Any] = []
    for g_idx, (pattern, repeats) in enumerate(layer_plan(cfg)):
        pat_p, pat_a = [], []
        for p_idx, bt in enumerate(pattern):
            reps_p = []
            axes_ref = None
            for r in range(repeats):
                bb = ParamBuilder(jax.random.fold_in(key, g_idx * 10000 + p_idx * 100 + r),
                                  dtype=param_dtype)
                blocks_mod.init_block(bb, bt, cfg)
                reps_p.append(bb.params)
                axes_ref = bb.axes
            if repeats > 1:
                stacked = jax.tree.map(lambda *xs: jnp.stack(xs, 0), *reps_p)
                pat_p.append(stacked)
                pat_a.append(stack_axes(axes_ref, "layer"))
            else:
                pat_p.append(reps_p[0])
                pat_a.append(axes_ref)
        groups_p.append(pat_p)
        groups_a.append(pat_a)
    b.params["groups"] = groups_p
    b.axes["groups"] = groups_a
    return b.params, b.axes


# ---------------------------------------------------------------------------
# cache
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> PyTree:
    """Cache pytree mirroring the group structure + one shared length scalar.

    Sliding-window ("*_local") layers allocate min(window, max_len) slots when
    ``cfg`` enables ring caches (beyond-paper memory optimization; see
    EXPERIMENTS.md §Perf) — baseline allocates full length everywhere.
    """
    groups = []
    for pattern, repeats in layer_plan(cfg):
        pat = []
        for bt in pattern:
            one = blocks_mod.init_block_cache(bt, cfg, batch, max_len, dtype)
            if repeats > 1:
                one = jax.tree.map(
                    lambda x: jnp.broadcast_to(x[None], (repeats,) + x.shape), one)
            pat.append(one)
        groups.append(pat)
    return {"length": jnp.zeros((), jnp.int32), "groups": groups}


def init_paged_cache(cfg: ModelConfig, num_blocks: int, block_size: int,
                     dtype=jnp.bfloat16) -> PyTree:
    """Paged serving cache: one (num_blocks, block_size, K, D) k/v pool per
    layer, mirroring the group structure. One *logical* block id indexes the
    same slot in every layer's pool, so the scheduler tracks a single block
    table per request. No length scalar: per-request lengths live host-side
    in the scheduler (``repro.engine.Engine``). Raises for non-GQA
    architectures.
    """
    groups = []
    for pattern, repeats in layer_plan(cfg):
        pat = []
        for bt in pattern:
            one = blocks_mod.init_paged_block_cache(bt, cfg, num_blocks,
                                                    block_size, dtype)
            if repeats > 1:
                one = jax.tree.map(
                    lambda x: jnp.broadcast_to(x[None], (repeats,) + x.shape), one)
            pat.append(one)
        groups.append(pat)
    return {"groups": groups}


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def forward(
    cfg: ModelConfig,
    params: PyTree,
    tokens: jax.Array,                       # (B, S) int32; audio: unused
    *,
    frontend_feats: Optional[jax.Array] = None,   # audio (B,T,feat) / vlm (B,P,d)
    positions: Optional[jax.Array] = None,
    mrope_positions: Optional[jax.Array] = None,
    cache: Optional[PyTree] = None,
    moe_transport=None,
    compute_dtype=jnp.bfloat16,
    constrain=None,                          # activation sharding constraint
    paged: Optional[PagedLayout] = None,     # serving: block-table cache view
    paged_kernel="auto",         # paged attention: pallas|ref|auto|callable
    recurrent: Optional[RecurrentLayout] = None,  # serving: valid-prefix layout
) -> Tuple[jax.Array, Optional[PyTree], jax.Array]:
    # ``constrain(x)`` pins (B, S, d) activations to the batch sharding at
    # the embedding, between layer groups, and inside the scanned body —
    # without it GSPMD is free to replicate the batch across the dp axis
    # when params are FSDP-sharded (observed: 16x redundant compute and a
    # full-batch logits buffer per chip; see EXPERIMENTS.md §Perf iter 1).
    if constrain is None:
        constrain = lambda t: t
    if cfg.frontend.kind == "audio_frames":
        x = jnp.einsum("btf,fd->btd", frontend_feats.astype(compute_dtype),
                       params["frontend_proj"].astype(compute_dtype))
    else:
        x = params["embed"].astype(compute_dtype)[tokens]
        x = x * jnp.asarray(cfg.d_model ** 0.5, compute_dtype)
        if cfg.frontend.kind == "vision_patches" and frontend_feats is not None:
            # splice precomputed image-patch embeddings over the first P slots
            p = frontend_feats.shape[1]
            x = jax.lax.dynamic_update_slice(
                x, frontend_feats.astype(compute_dtype), (0, 0, 0))
            del p

    x = constrain(x)
    B, S = x.shape[0], x.shape[1]
    if paged is not None:
        # paged cache carries no global length scalar — positions are
        # per-request (starts) and lengths live in the scheduler
        length = None
        positions = paged.token_positions(S)
    elif recurrent is not None:
        # constant-size state: positions are per-request (starts); the
        # shared length scalar is frozen — host-side entry.pos is the truth
        length = cache["length"]
        positions = recurrent.token_positions(S)
    else:
        length = cache["length"] if cache is not None else None
        if positions is None:
            off = length if cache is not None else jnp.int32(0)
            positions = jnp.arange(S, dtype=jnp.int32)[None, :] + off

    plan = layer_plan(cfg)
    new_groups: List[Any] = []
    aux_total = jnp.zeros((), jnp.float32)

    for g_idx, (pattern, repeats) in enumerate(plan):
        # Cast the whole layer stack to the compute dtype BEFORE the scan
        # (§Perf A1/C1): FSDP all-gathers and per-layer weight reads inside
        # the loop then move bf16 — half the ICI and HBM bytes of gathering
        # f32 masters and casting per layer.
        pat_params = jax.tree.map(
            lambda t: t.astype(compute_dtype)
            if t.dtype in (jnp.float32, jnp.bfloat16) else t,
            params["groups"][g_idx])
        pat_cache = cache["groups"][g_idx] if cache is not None else None

        def body(carry, per_layer, pattern=pattern):
            x_c, aux_c = carry
            lp, lc = per_layer
            new_lc = []
            for p_idx, bt in enumerate(pattern):
                c_in = lc[p_idx] if lc is not None else None
                x_c, c_out, aux = blocks_mod.apply_block(
                    bt, lp[p_idx],
                    x_c, cfg, cache=c_in, length=length,
                    positions=positions, mrope_positions=mrope_positions,
                    moe_transport=moe_transport, paged=paged,
                    paged_kernel=paged_kernel, recurrent=recurrent)
                x_c = constrain(x_c)
                new_lc.append(c_out)
            return (x_c, aux_c + aux), new_lc

        # Decode (S==1) unrolls the layer loop: a scanned cache is xs->ys,
        # which double-buffers the FULL per-layer KV cache every step
        # (~170 GiB temps at 32k x B128). Unrolled, each layer's update is
        # DUS(DS(stacked)) — in place on the donated cache buffer. Paged
        # steps always unroll for the same reason: the pool is the dominant
        # buffer and must update in place on the donated argument.
        # Pure-recurrent stacks (xLSTM, SSM-only) always scan: the state is
        # constant-size (double-buffering is cheap) and scan-vs-unroll round
        # differently at 1 bf16 ulp — keeping every path (prefill, S==1
        # decode, masked serving chunks) on the scan is what makes chunked
        # recurrent serving bitwise-identical to the contiguous reference.
        pure_recurrent = all(
            bt in blocks_mod.RECURRENT_BLOCK_TYPES for bt in pattern)
        unroll = cache is not None and (
            paged is not None or (S == 1 and not pure_recurrent))
        if repeats > 1 and unroll:
            new_pat_cache = pat_cache
            for r in range(repeats):
                lp = jax.tree.map(lambda t: t[r], pat_params)
                lc = jax.tree.map(lambda t: t[r], new_pat_cache)
                (x, aux_total), out_lc = body((x, aux_total), (lp, lc))
                new_pat_cache = jax.tree.map(
                    lambda full, one: full.at[r].set(one),
                    new_pat_cache, out_lc)
        elif repeats > 1:
            scan_body = body
            if cfg.remat == "full":
                scan_body = jax.checkpoint(body)
            (x, aux_total), new_pat_cache = jax.lax.scan(
                scan_body, (x, aux_total), (pat_params, pat_cache))
        else:
            (x, aux_total), new_pat_cache = body((x, aux_total),
                                                 (pat_params, pat_cache))
        new_groups.append(new_pat_cache)

    x = rms_norm(x, params["final_norm"].astype(compute_dtype), cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"].astype(compute_dtype))
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, params["head"].astype(compute_dtype))
    logits = softcap(logits.astype(jnp.float32), cfg.final_logit_softcap)

    new_cache = None
    if cache is not None:
        if paged is not None:
            new_cache = {"groups": new_groups}
        elif recurrent is not None:
            # per-request progress is host-side (entry.pos); the shared
            # scalar stays frozen so slot rows never skew
            new_cache = {"length": cache["length"], "groups": new_groups}
        else:
            new_cache = {"length": length + S, "groups": new_groups}
    return logits, new_cache, aux_total


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------

def loss_fn(cfg: ModelConfig, params: PyTree, batch: Dict[str, jax.Array],
            moe_transport=None, constrain=None
            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Next-token CE (decoder) or masked-frame CE (encoder). batch:
    {tokens (B,S), labels (B,S), [features], [mrope_positions]}."""
    logits, _, aux = forward(
        cfg, params, batch["tokens"],
        frontend_feats=batch.get("features"),
        mrope_positions=batch.get("mrope_positions"),
        moe_transport=moe_transport, constrain=constrain)
    labels = batch["labels"]
    if not cfg.is_encoder:
        logits = logits[:, :-1]
        labels = labels[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    n_cls = logits.shape[-1]
    ce = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    mask = (labels >= 0) & (labels < n_cls)
    ce = jnp.where(mask, ce, 0.0)
    denom = jnp.maximum(mask.sum(), 1)
    loss = ce.sum() / denom + aux
    return loss, {"ce": ce.sum() / denom, "aux": aux,
                  "tokens": denom.astype(jnp.float32)}


def decode_step(cfg: ModelConfig, params: PyTree, cache: PyTree,
                token: jax.Array, moe_transport=None,
                mrope_positions: Optional[jax.Array] = None,
                compute_dtype=jnp.bfloat16, constrain=None
                ) -> Tuple[jax.Array, PyTree]:
    """One-token decode. token: (B, 1) int32 -> (logits (B,1,V), new_cache)."""
    logits, new_cache, _ = forward(cfg, params, token, cache=cache,
                                   moe_transport=moe_transport,
                                   mrope_positions=mrope_positions,
                                   compute_dtype=compute_dtype,
                                   constrain=constrain)
    return logits, new_cache
