"""Mixture-of-experts FFN: router, capacity math, and the reference (oracle)
execution path.

The *transport* of tokens/weights between devices is the Two-Chains jam layer
(``repro.core.dispatch``): ``moe_ffn`` accepts a ``transport`` callable so the
model definition is independent of how bytes move. The default here is the
single-device oracle (dense masked einsum over all experts) — the pure-jnp
``ref`` against which both shard_map transports and the Pallas moe_jam kernel
are validated.
"""
from __future__ import annotations

import math
from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.models.common import ParamBuilder, act_fn


class RouteResult(NamedTuple):
    expert_ids: jax.Array    # (N, k) int32
    gates: jax.Array         # (N, k) f32, normalized over k
    aux_loss: jax.Array      # () load-balance aux
    z_loss: jax.Array        # () router z-loss


def init_moe(b: ParamBuilder, d_model: int, m: MoEConfig) -> None:
    b.param("router", (d_model, m.num_experts), ("embed", "expert"))
    e = m.num_experts
    b.param("w_gate", (e, d_model, m.expert_ff), ("expert", "embed", "moe_ff"), fan_in=d_model)
    b.param("w_up", (e, d_model, m.expert_ff), ("expert", "embed", "moe_ff"), fan_in=d_model)
    b.param("w_down", (e, m.expert_ff, d_model), ("expert", "moe_ff", "embed"), fan_in=m.expert_ff)
    if m.num_shared > 0:
        ff = (m.shared_ff or m.expert_ff) * m.num_shared
        b.param("ws_gate", (d_model, ff), ("embed", "ff"))
        b.param("ws_up", (d_model, ff), ("embed", "ff"))
        b.param("ws_down", (ff, d_model), ("ff", "embed"))


def route_topk(x: jax.Array, router_w: jax.Array, m: MoEConfig) -> RouteResult:
    """x: (N, d) -> top-k routing with Switch-style aux losses (float32 math)."""
    logits = jnp.einsum("nd,de->ne", x.astype(jnp.float32),
                        router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gates, ids = jax.lax.top_k(probs, m.top_k)               # (N,k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    # load-balance: E * sum_e (frac tokens to e) * (mean prob of e)
    e = m.num_experts
    one_hot = jax.nn.one_hot(ids[:, 0], e, dtype=jnp.float32)  # primary expert
    f = one_hot.mean(0)
    p = probs.mean(0)
    aux = e * jnp.sum(f * p) * m.router_aux_coef
    z = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2) * m.router_z_coef
    return RouteResult(ids.astype(jnp.int32), gates, aux, z)


def expert_capacity(n_tokens: int, m: MoEConfig, n_shards: int = 1) -> int:
    """Per-expert capacity, padded to an MXU-aligned multiple of 8."""
    c = math.ceil(n_tokens * m.top_k * m.capacity_factor / m.num_experts)
    return max(8, -(-c // 8) * 8)


def expert_ffn(w_gate: jax.Array, w_up: jax.Array, w_down: jax.Array,
               x: jax.Array, act: str = "silu") -> jax.Array:
    """Batched expert FFN: x (E, C, d) with per-expert weights (E, d, f)."""
    g = jnp.einsum("ecd,edf->ecf", x, w_gate)
    u = jnp.einsum("ecd,edf->ecf", x, w_up)
    h = act_fn(act)(g) * u
    return jnp.einsum("ecf,efd->ecd", h, w_down)


def build_dispatch(ids: jax.Array, gates: jax.Array, n_experts: int,
                   capacity: int) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Capacity-bucketed dispatch plan.

    Returns (slot (N,k) int32 in [0, E*C] — E*C is the drop slot,
             keep (N,k) bool, position-in-expert rank (N,k)).
    """
    n, k = ids.shape
    flat = ids.reshape(-1)                                    # (N*k,)
    one_hot = jax.nn.one_hot(flat, n_experts, dtype=jnp.int32)
    rank = (jnp.cumsum(one_hot, axis=0) - one_hot) * one_hot  # pos within expert
    rank = rank.sum(-1).reshape(n, k)
    keep = rank < capacity
    slot = jnp.where(keep, ids * capacity + rank, n_experts * capacity)
    return slot.astype(jnp.int32), keep, rank


def moe_ffn_oracle(params, x: jax.Array, m: MoEConfig, act: str = "silu",
                   capacity: Optional[int] = None,
                   token_mask: Optional[jax.Array] = None
                   ) -> Tuple[jax.Array, jax.Array]:
    """Reference MoE: capacity-bucketed single-device execution.

    x: (B, S, d). Returns (out, aux_losses_sum). This is the oracle for the
    jam transports; it performs the same capacity/drop math so distributed
    results match it exactly.

    ``token_mask`` (B, S) bool marks real tokens: masked-out tokens (paged
    serving's padding columns) route to the drop slot with zero gates, so
    they consume no expert capacity and contribute nothing — without it a
    padding column can steal a capacity slot from a real token and change
    its output.
    """
    b, s, d = x.shape
    xf = x.reshape(-1, d)
    n = xf.shape[0]
    r = route_topk(xf, params["router"], m)
    ids, gates = r.expert_ids, r.gates
    if token_mask is not None:
        tm = token_mask.reshape(-1)
        # out-of-range expert id => all-zero one_hot in build_dispatch =>
        # rank 0 and slot == the drop slot: no capacity consumed
        ids = jnp.where(tm[:, None], ids, jnp.int32(m.num_experts))
        gates = gates * tm[:, None]
    c = capacity or expert_capacity(n, m)
    slot, keep, _ = build_dispatch(ids, gates, m.num_experts, c)
    buf = jnp.zeros((m.num_experts * c + 1, d), x.dtype)
    buf = buf.at[slot.reshape(-1)].set(jnp.repeat(xf, m.top_k, axis=0),
                                       mode="drop")
    buf = buf[:-1].reshape(m.num_experts, c, d)
    out_buf = expert_ffn(params["w_gate"], params["w_up"], params["w_down"],
                         buf, act)
    out_buf = jnp.concatenate([out_buf.reshape(-1, d),
                               jnp.zeros((1, d), x.dtype)], axis=0)
    gathered = out_buf[slot.reshape(-1)].reshape(n, m.top_k, d)
    w = (gates * keep).astype(x.dtype)
    y = jnp.einsum("nkd,nk->nd", gathered, w)
    if m.num_shared > 0:
        g = jnp.einsum("nd,df->nf", xf, params["ws_gate"])
        u = jnp.einsum("nd,df->nf", xf, params["ws_up"])
        y = y + jnp.einsum("nf,fd->nd", act_fn(act)(g) * u, params["ws_down"])
    return y.reshape(b, s, d), r.aux_loss + r.z_loss


MoETransport = Callable[..., Tuple[jax.Array, jax.Array]]


def moe_ffn(params, x: jax.Array, m: MoEConfig, act: str = "silu",
            transport: Optional[MoETransport] = None,
            token_mask: Optional[jax.Array] = None
            ) -> Tuple[jax.Array, jax.Array]:
    """MoE FFN with pluggable jam transport (None => single-device oracle).

    ``token_mask`` (B, S) bool marks real tokens; both paths honor it with
    the same routing rule (masked tokens hit the drop slot with zero gates,
    consuming no expert capacity — see ``core.dispatch._mask_route``), so
    paged MoE serving works on any mesh (docs/fabric.md).
    """
    if transport is None:
        return moe_ffn_oracle(params, x, m, act, token_mask=token_mask)
    if token_mask is None:
        return transport(params, x, m, act)
    return transport(params, x, m, act, token_mask=token_mask)
