"""Decoder/encoder block assembly per block type.

A *block type* is a string key ("attn_full", "attn_local", "attn_moe",
"mla_dense", "mla_moe", "hybrid_local", "hybrid_full", "mlstm", "slstm",
"enc") — ``repro.models.model.layer_plan`` arranges them into repeated-pattern
groups that are executed under ``lax.scan`` with stacked parameters.

Every block has the same signature so the scan body can be uniform:
    apply(bt, params, x, cfg, cache, length, positions, mrope, transport)
      -> (x_out, new_cache_dict, aux_loss)
cache dicts hold raw arrays (no dataclass) so they stack/slice trivially.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import mlp as mlp_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models import xlstm as xlstm_mod
from repro.models.common import ParamBuilder, rms_norm
from repro.models.kvcache import (KVCache, MLACache, PagedKVCache,
                                  PagedLayout, RecurrentLayout, SSMCache)

Cache = Optional[Dict[str, Any]]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_block(b: ParamBuilder, bt: str, cfg: ModelConfig) -> None:
    d = cfg.d_model
    b.param("ln1", (d,), ("embed",), init="zeros")
    if bt in ("mlstm",):
        xlstm_mod.init_mlstm(b.scope("mlstm"), d, cfg.xlstm)
        return
    if bt in ("slstm",):
        xlstm_mod.init_slstm(b.scope("slstm"), d, cfg.xlstm)
        return
    if bt == "ssm":
        # pure selective-SSM block (mamba): norm -> SSM residual, plus an
        # optional MLP residual when the arch carries one (d_ff > 0)
        ssm_mod.init_ssm(b.scope("ssm"), d, cfg.ssm)
        if cfg.d_ff:
            b.param("ln2", (d,), ("embed",), init="zeros")
            mlp_mod.init_mlp(b.scope("mlp"), d, cfg.d_ff, cfg.mlp_gated)
        return
    b.param("ln2", (d,), ("embed",), init="zeros")
    a = cfg.attention
    if bt.startswith("mla"):
        attn.init_mla(b.scope("attn"), d, a)
    else:
        attn.init_gqa(b.scope("attn"), d, a)
    if bt.startswith("hybrid"):
        ssm_mod.init_ssm(b.scope("ssm"), d, cfg.ssm)
    if bt.endswith("_moe"):
        moe_mod.init_moe(b.scope("moe"), d, cfg.moe)
    else:
        mlp_mod.init_mlp(b.scope("mlp"), d, cfg.d_ff, cfg.mlp_gated)


# ---------------------------------------------------------------------------
# cache init (raw-array dicts; length lives at model level)
# ---------------------------------------------------------------------------

def init_block_cache(bt: str, cfg: ModelConfig, batch: int, max_len: int,
                     dtype=jnp.bfloat16) -> Dict[str, Any]:
    a = cfg.attention
    c: Dict[str, Any] = {}
    if bt in ("mlstm",):
        kc = xlstm_mod.mlstm_init_cache(cfg.d_model, cfg.xlstm, batch, dtype)
        return {"conv": kc.conv, "state": kc.state, "n": kc.extra[0], "m": kc.extra[1]}
    if bt in ("slstm",):
        kc = xlstm_mod.slstm_init_cache(cfg.d_model, cfg.xlstm, batch, dtype)
        return {"state": kc.state, "c": kc.extra[0], "n": kc.extra[1], "m": kc.extra[2]}
    if bt == "ssm":
        sc = ssm_mod.ssm_init_cache(cfg.d_model, cfg.ssm, batch, dtype)
        return {"conv": sc.conv, "state": sc.state}
    if bt.startswith("mla"):
        c["c_kv"] = jnp.zeros((batch, max_len, a.kv_lora_rank), dtype)
        c["k_rope"] = jnp.zeros((batch, max_len, a.qk_rope_head_dim), dtype)
    else:
        kv_shape = (batch, max_len, a.num_kv_heads, a.head_dim)
        c["k"] = jnp.zeros(kv_shape, dtype)
        c["v"] = jnp.zeros(kv_shape, dtype)
    if bt.startswith("hybrid"):
        sc = ssm_mod.ssm_init_cache(cfg.d_model, cfg.ssm, batch, dtype)
        c["conv"] = sc.conv
        c["state"] = sc.state
    return c


# ---------------------------------------------------------------------------
# paged cache init (serving pool; GQA block types only)
# ---------------------------------------------------------------------------

# Block types whose cache is plain GQA k/v — the ones the paged serving
# subsystem supports (ISSUE 2: GQA first; MLA/SSM/xLSTM archs stay on the
# contiguous Server).
PAGED_BLOCK_TYPES = ("attn_full", "attn_local", "attn_moe")

# Block types whose per-request state is constant-size (conv history +
# recurrent state, no seq-length axis) — the ones the recurrent serving
# backend supports. Hybrid blocks carry seq-sized KV leaves alongside the
# SSM state, so they are excluded (use cache='slots' for those archs).
RECURRENT_BLOCK_TYPES = ("mlstm", "slstm", "ssm")


def init_paged_block_cache(bt: str, cfg: ModelConfig, num_blocks: int,
                           block_size: int, dtype=jnp.bfloat16) -> Dict[str, Any]:
    if bt not in PAGED_BLOCK_TYPES:
        raise ValueError(
            f"paged serving supports GQA block types {PAGED_BLOCK_TYPES}, "
            f"got {bt!r} — use the contiguous Server for this arch")
    a = cfg.attention
    shape = (num_blocks, block_size, a.num_kv_heads, a.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


# ---------------------------------------------------------------------------
# apply
# ---------------------------------------------------------------------------

def apply_block(
    bt: str,
    params,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    cache: Cache = None,
    length: Optional[jax.Array] = None,
    positions: Optional[jax.Array] = None,
    mrope_positions: Optional[jax.Array] = None,
    moe_transport=None,
    paged: Optional[PagedLayout] = None,
    paged_kernel="auto",         # str kind or a sharded-kernel callable
    recurrent: Optional[RecurrentLayout] = None,
) -> Tuple[jax.Array, Cache, jax.Array]:
    a = cfg.attention
    zero = jnp.zeros((), jnp.float32)

    if paged is not None:
        return _apply_block_paged(bt, params, x, cfg, cache, paged,
                                  moe_transport, paged_kernel)

    if recurrent is not None and bt not in RECURRENT_BLOCK_TYPES:
        raise ValueError(
            f"block type {bt!r} has no recurrent serving path — only "
            f"{RECURRENT_BLOCK_TYPES} carry constant-size state; use "
            "cache='paged' or 'slots' for this arch")
    valid = recurrent.token_valid(x.shape[1]) if recurrent is not None else None

    if bt == "mlstm":
        h = rms_norm(x, params["ln1"], cfg.norm_eps)
        kc = None
        if cache is not None:
            kc = SSMCache(cache["conv"], cache["state"],
                          (cache["n"], cache["m"]), length)
        y, nkc = xlstm_mod.mlstm_forward(params["mlstm"], h, cfg.xlstm,
                                         cache=kc, valid=valid)
        new_cache = None
        if nkc is not None:
            new_cache = {"conv": nkc.conv, "state": nkc.state,
                         "n": nkc.extra[0], "m": nkc.extra[1]}
        return x + y, new_cache, zero

    if bt == "slstm":
        h = rms_norm(x, params["ln1"], cfg.norm_eps)
        kc = None
        if cache is not None:
            kc = SSMCache(cache.get("conv", jnp.zeros((x.shape[0], 0, 0), x.dtype)),
                          cache["state"], (cache["c"], cache["n"], cache["m"]),
                          length)
        y, nkc = xlstm_mod.slstm_forward(params["slstm"], h, cfg.xlstm,
                                         cache=kc, valid=valid)
        new_cache = None
        if nkc is not None:
            new_cache = {"state": nkc.state, "c": nkc.extra[0],
                         "n": nkc.extra[1], "m": nkc.extra[2]}
        return x + y, new_cache, zero

    if bt == "ssm":
        h = rms_norm(x, params["ln1"], cfg.norm_eps)
        sc = None
        if cache is not None:
            sc = SSMCache(cache["conv"], cache["state"], None, length)
        y, nsc = ssm_mod.ssm_forward(params["ssm"], h, cfg.ssm,
                                     cache=sc, valid=valid)
        new_cache = None
        if nsc is not None:
            new_cache = {"conv": nsc.conv, "state": nsc.state}
        x = x + y
        if cfg.d_ff:
            h2 = rms_norm(x, params["ln2"], cfg.norm_eps)
            x = x + mlp_mod.mlp(params["mlp"], h2, cfg.act, cfg.mlp_gated)
        return x, new_cache, zero

    # ---- attention (+ optional parallel SSM) sub-layer ----
    h = rms_norm(x, params["ln1"], cfg.norm_eps)
    causal = not cfg.is_encoder
    window = None
    if bt.endswith("_local") or (bt.startswith("attn_local")) or bt == "hybrid_local":
        window = a.sliding_window
    new_cache: Dict[str, Any] = {} if cache is not None else None

    if bt.startswith("mla"):
        mc = None
        if cache is not None:
            mc = MLACache(cache["c_kv"], cache["k_rope"], length)
        y_attn, nmc = attn.mla_attention(params["attn"], h, a, causal=causal,
                                         cache=mc, positions=positions,
                                         norm_eps=cfg.norm_eps)
        if nmc is not None:
            new_cache.update(c_kv=nmc.c_kv, k_rope=nmc.k_rope)
    else:
        kv = None
        if cache is not None:
            kv = KVCache(cache["k"], cache["v"], length)
        y_attn, nkv = attn.gqa_attention(params["attn"], h, a, causal=causal,
                                         window=window, cache=kv,
                                         positions=positions,
                                         mrope_positions=mrope_positions)
        if nkv is not None:
            new_cache.update(k=nkv.k, v=nkv.v)

    if bt.startswith("hybrid"):
        sc = None
        if cache is not None:
            sc = SSMCache(cache["conv"], cache["state"], None, length)
        y_ssm, nsc = ssm_mod.ssm_forward(params["ssm"], h, cfg.ssm, cache=sc)
        # hymba: mean-fuse the parallel attention and mamba head outputs
        y_attn = 0.5 * (y_attn + y_ssm)
        if nsc is not None:
            new_cache.update(conv=nsc.conv, state=nsc.state)

    x = x + y_attn

    # ---- FFN sub-layer ----
    h2 = rms_norm(x, params["ln2"], cfg.norm_eps)
    aux = zero
    if bt.endswith("_moe"):
        y_ffn, aux = moe_mod.moe_ffn(params["moe"], h2, cfg.moe, cfg.act,
                                     transport=moe_transport)
    else:
        y_ffn = mlp_mod.mlp(params["mlp"], h2, cfg.act, cfg.mlp_gated)
    return x + y_ffn, new_cache, aux


def _apply_block_paged(bt: str, params, x: jax.Array, cfg: ModelConfig,
                       cache: Cache, paged: PagedLayout,
                       moe_transport, paged_kernel="auto"
                       ) -> Tuple[jax.Array, Cache, jax.Array]:
    """Paged-serving variant: GQA attention through the block pool.

    Same residual structure as the contiguous path; only the attention
    sub-layer differs (pool scatter + the stash-resident kernel or its
    gather-then-dense oracle instead of contiguous append).
    """
    if bt not in PAGED_BLOCK_TYPES:
        raise ValueError(f"block type {bt!r} has no paged path")
    a = cfg.attention
    window = a.sliding_window if bt.endswith("_local") else None
    h = rms_norm(x, params["ln1"], cfg.norm_eps)
    pkv = PagedKVCache(cache["k"], cache["v"], paged.block_size)
    y_attn, npkv = attn.gqa_paged_attention(params["attn"], h, a,
                                            cache=pkv, layout=paged,
                                            window=window,
                                            kernel=paged_kernel)
    x = x + y_attn
    h2 = rms_norm(x, params["ln2"], cfg.norm_eps)
    if bt.endswith("_moe"):
        # mask the padding columns out of routing so they cannot steal
        # expert capacity from real tokens (same drop-slot rule on the
        # oracle and every jam transport — docs/fabric.md)
        y_ffn, aux = moe_mod.moe_ffn(params["moe"], h2, cfg.moe, cfg.act,
                                     transport=moe_transport,
                                     token_mask=paged.token_valid(x.shape[1]))
    else:
        y_ffn = mlp_mod.mlp(params["mlp"], h2, cfg.act, cfg.mlp_gated)
        aux = jnp.zeros((), jnp.float32)
    return x + y_ffn, {"k": npkv.k_pool, "v": npkv.v_pool}, aux
