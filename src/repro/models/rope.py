"""Rotary position embeddings: standard, partial (stablelm), and M-RoPE (qwen2-vl)."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def rope_freqs(head_dim: int, theta: float, rotary_pct: float = 1.0) -> jax.Array:
    """Inverse frequencies for the rotated sub-dimension."""
    rot_dim = int(head_dim * rotary_pct)
    rot_dim -= rot_dim % 2
    if rot_dim == 0:
        return jnp.zeros((0,), jnp.float32)
    exponent = jnp.arange(0, rot_dim, 2, dtype=jnp.float32) / rot_dim
    return 1.0 / (theta ** exponent)


def _rotate_half(x: jax.Array) -> jax.Array:
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([-x2, x1], axis=-1)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float,
               rotary_pct: float = 1.0) -> jax.Array:
    """Apply RoPE. x: (..., S, H, D); positions: broadcastable to (..., S)."""
    head_dim = x.shape[-1]
    inv = rope_freqs(head_dim, theta, rotary_pct)
    rot_dim = 2 * inv.shape[0]
    if rot_dim == 0:
        return x
    ang = positions[..., :, None].astype(jnp.float32) * inv  # (..., S, rot/2)
    ang = jnp.concatenate([ang, ang], axis=-1)               # (..., S, rot)
    cos = jnp.cos(ang)[..., :, None, :]                      # (..., S, 1, rot)
    sin = jnp.sin(ang)[..., :, None, :]
    x_rot, x_pass = x[..., :rot_dim], x[..., rot_dim:]
    x_f = x_rot.astype(jnp.float32)
    out = x_f * cos + _rotate_half(x_f) * sin
    return jnp.concatenate([out.astype(x.dtype), x_pass], axis=-1)


def apply_mrope(x: jax.Array, positions_3d: jax.Array, theta: float,
                sections: Tuple[int, int, int]) -> jax.Array:
    """Multimodal RoPE (qwen2-vl): three position streams (t, h, w).

    x: (B, S, H, D). positions_3d: (3, B, S). ``sections`` splits the D/2
    frequency slots among (t, h, w); each slot's angle uses its stream's
    position. For pure-text positions the three streams coincide and this
    reduces to standard RoPE.
    """
    head_dim = x.shape[-1]
    inv = rope_freqs(head_dim, theta, 1.0)                    # (D/2,)
    half = inv.shape[0]
    assert sum(sections) == half, (sections, half)
    # stream index for every frequency slot
    sect_ids = jnp.concatenate([
        jnp.full((s,), i, jnp.int32) for i, s in enumerate(sections)
    ])                                                        # (D/2,)
    pos = positions_3d.astype(jnp.float32)                    # (3, B, S)
    pos_per_slot = pos[sect_ids, :, :]                        # (D/2, B, S)
    ang = jnp.einsum("dbs,d->bsd", pos_per_slot, inv)         # (B, S, D/2)
    ang = jnp.concatenate([ang, ang], axis=-1)                # (B, S, D)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x_f = x.astype(jnp.float32)
    out = x_f * cos + _rotate_half(x_f) * sin
    return out.astype(x.dtype)


def text_mrope_positions(batch: int, seq: int,
                         offset: Optional[jax.Array] = None) -> jax.Array:
    """(3, B, S) position ids where all three streams share text positions."""
    p = jnp.arange(seq, dtype=jnp.int32)[None, :].repeat(batch, axis=0)
    if offset is not None:
        p = p + offset[:, None].astype(jnp.int32)
    return jnp.broadcast_to(p[None], (3, batch, seq))
