"""stablelm-3b: dense, 32L d_model=2560 32H (MHA kv=32) d_ff=6912 vocab=50304.

Partial rotary embedding (25%). [hf:stabilityai/stablelm-2-1_6b; unverified]
"""
from repro.configs.base import AttentionConfig, ModelConfig

ARCH_ID = "stablelm-3b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="dense",
        num_layers=32,
        d_model=2560,
        d_ff=6912,
        vocab_size=50304,
        attention=AttentionConfig(
            kind="gqa", num_heads=32, num_kv_heads=32, head_dim=80,
            rotary_pct=0.25, rope_theta=10000.0,
        ),
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        num_layers=2,
        d_model=64,
        d_ff=96,
        vocab_size=256,
        attention=AttentionConfig(
            kind="gqa", num_heads=4, num_kv_heads=4, head_dim=16, rotary_pct=0.25,
        ),
        remat="none",
    )
