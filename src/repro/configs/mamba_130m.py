"""mamba-130m: ssm, 24L d_model=768 vocab=50280.

Pure selective-SSM stack (every layer a mamba block, no attention, no
separate FFN — the block's gated up-projection carries the capacity).
The smallest pure-recurrent arch in the zoo; the recurrent serving
backend's reference config. [arXiv:2312.00752; unverified]
"""
from repro.configs.base import ModelConfig, SSMConfig

ARCH_ID = "mamba-130m"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="ssm",
        num_layers=24,
        d_model=768,
        d_ff=0,
        vocab_size=50280,
        attention=None,
        ssm=SSMConfig(state_dim=16, conv_width=4, expand=2),
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="ssm",
        num_layers=2,
        d_model=64,
        d_ff=0,
        vocab_size=256,
        attention=None,
        ssm=SSMConfig(state_dim=4, conv_width=4, expand=2),
        remat="none",
    )
