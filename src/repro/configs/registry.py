"""Architecture registry: --arch <id> resolution + per-arch shape applicability."""
from __future__ import annotations

from typing import Callable, Dict, Tuple

from repro.configs import (
    deepseek_v2_lite_16b,
    gemma3_4b,
    granite_20b,
    hubert_xlarge,
    hymba_1p5b,
    llama32_1b,
    mamba_130m,
    olmoe_1b_7b,
    qwen2_vl_72b,
    stablelm_3b,
    xlstm_1p3b,
)
from repro.configs.base import SHAPES, ModelConfig, ShapeConfig

_MODULES = (
    gemma3_4b, granite_20b, llama32_1b, stablelm_3b, deepseek_v2_lite_16b,
    olmoe_1b_7b, hymba_1p5b, xlstm_1p3b, mamba_130m, hubert_xlarge,
    qwen2_vl_72b,
)

ARCHS: Dict[str, Callable[[], ModelConfig]] = {m.ARCH_ID: m.config for m in _MODULES}
SMOKES: Dict[str, Callable[[], ModelConfig]] = {m.ARCH_ID: m.smoke for m in _MODULES}

# long_500k is only runnable with sub-quadratic attention. Pure full-attention
# archs skip it (DESIGN.md §5). gemma3 runs it (5:1 sliding-window layers);
# hymba (hybrid) and xlstm/mamba (recurrent) run it.
_LONG_OK = {"gemma3-4b", "hymba-1.5b", "xlstm-1.3b", "mamba-130m"}
# Encoder-only archs have no decode step.
_ENCODER_ONLY = {"hubert-xlarge"}


def default_cache_backend(cfg: ModelConfig) -> str:
    """The serving Engine's default sequence-state backend per model family.

    Recurrent stacks (xLSTM, pure SSM) carry constant-size state — the
    recurrent backend serves them exactly AND preempts for free. Archs the
    paged pool cannot hold (MLA latents, hybrid attn+SSM, mrope position
    streams) fall back to the contiguous slots rows. Plain-GQA archs get
    the paged pool (docs/serving.md has the full backend table).
    """
    if cfg.xlstm is not None or (cfg.ssm is not None and cfg.attention is None):
        return "recurrent"
    a = cfg.attention
    if cfg.parallel_ssm_attn or (a is not None and (a.kind == "mla" or a.mrope)):
        return "slots"
    return "paged"


def get_config(arch: str) -> ModelConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCHS)}")
    return ARCHS[arch]()


def get_smoke(arch: str) -> ModelConfig:
    return SMOKES[arch]()


def cell_status(arch: str, shape_name: str) -> Tuple[bool, str]:
    """(runnable, reason) for an (arch x shape) cell."""
    shape = SHAPES[shape_name]
    if arch in _ENCODER_ONLY and shape.kind == "decode":
        return False, "encoder-only: no decode step"
    if shape_name == "long_500k" and arch not in _LONG_OK:
        return False, "pure full-attention: long_500k needs sub-quadratic attention"
    return True, ""


def all_cells():
    """Yield (arch, shape, runnable, reason) for the full 40-cell matrix."""
    for arch in ARCHS:
        for shape_name in SHAPES:
            ok, why = cell_status(arch, shape_name)
            yield arch, shape_name, ok, why
