"""The paper's own testbed, transcribed as a config.

Two Arm servers, ConnectX-6 200 Gb/s IB back-to-back; jams = Server-Side Sum
and Indirect Put active messages. On TPU this becomes the 2-device jam
micro-benchmark mesh used by ``benchmarks/`` to reproduce Figs 5-14: message
frames over the `model` axis, handlers from the benchmark jam package.

Paper constants used by the benchmark harness & cost model
(Section VI-C of the paper, and the assignment's TPU v5e targets):
"""
from dataclasses import dataclass


@dataclass(frozen=True)
class PaperTestbed:
    # --- the paper's hardware (for faithful-unit reporting) ---
    nic_gbps: float = 200.0            # ConnectX-6 IB
    code_bytes_indirect_put: int = 1408  # §VII-A: Indirect Put shipped code size
    frame_align: int = 64              # messages sized to nearest 64B
    llc_bytes: int = 8 * 2**20         # 8MB shared LLC
    # paper's headline numbers (for EXPERIMENTS.md validation targets)
    stash_latency_gain: float = 0.31   # up to 31% latency reduction
    stash_rate_gain: float = 0.92      # up to 92% message-rate increase
    stash_tail_gain: float = 2.4       # tail latency 2.4x better
    wfe_cycle_gain: float = 3.8        # up to 3.8x fewer cycles
    injected_small_overhead: float = 0.40  # ~40% loss at small payloads
    am_put_latency_overhead: float = 0.015  # <=1.5% vs raw put

    # --- TPU v5e targets (assignment constants) ---
    tpu_bf16_flops: float = 197e12     # per chip
    tpu_hbm_gbps: float = 819e9       # bytes/s
    tpu_ici_gbps: float = 50e9        # bytes/s per link


TESTBED = PaperTestbed()
