"""llama3.2-1b: dense, 16L d_model=2048 32H (GQA kv=8) d_ff=8192 vocab=128256.

[hf:meta-llama/Llama-3.2-1B; unverified]
"""
from repro.configs.base import AttentionConfig, ModelConfig

ARCH_ID = "llama3.2-1b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="dense",
        num_layers=16,
        d_model=2048,
        d_ff=8192,
        vocab_size=128256,
        attention=AttentionConfig(
            kind="gqa", num_heads=32, num_kv_heads=8, head_dim=64,
            rope_theta=500000.0,
        ),
        tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        num_layers=2,
        d_model=64,
        d_ff=128,
        vocab_size=256,
        attention=AttentionConfig(kind="gqa", num_heads=4, num_kv_heads=2, head_dim=16),
        tie_embeddings=True,
        remat="none",
    )
