"""hubert-xlarge: audio encoder-only, 48L d_model=1280 16H (MHA) d_ff=5120 vocab=504.

Same transformer arch as wav2vec2; vocab is the masked-prediction codebook.
[arXiv:2106.07447; unverified]

The conv waveform frontend is a STUB per the assignment: ``input_specs()``
provides precomputed frame features (batch, frames, 512); the model owns only
the 512->1280 feature projection and the encoder stack. Encoder-only: no
causal mask, no KV cache, no decode shapes.
"""
from repro.configs.base import AttentionConfig, FrontendConfig, ModelConfig

ARCH_ID = "hubert-xlarge"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="audio",
        num_layers=48,
        d_model=1280,
        d_ff=5120,
        vocab_size=504,
        attention=AttentionConfig(
            kind="gqa", num_heads=16, num_kv_heads=16, head_dim=80,
            rotary_pct=0.0,   # hubert uses (conv) absolute positions; stub: none
        ),
        frontend=FrontendConfig(kind="audio_frames", feature_dim=512),
        is_encoder=True,
        act="gelu",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="audio",
        num_layers=2,
        d_model=64,
        d_ff=128,
        vocab_size=64,
        attention=AttentionConfig(
            kind="gqa", num_heads=4, num_kv_heads=4, head_dim=16, rotary_pct=0.0,
        ),
        frontend=FrontendConfig(kind="audio_frames", feature_dim=32),
        is_encoder=True,
        act="gelu",
        remat="none",
    )
