"""deepseek-v2-lite-16b: MoE, 27L d_model=2048 16H d_ff=1408(expert) vocab=102400.

MLA attention (kv_lora_rank=512, no q compression in Lite), 64 routed experts
top-6 + 2 shared experts, first layer dense (d_ff=10944).
[arXiv:2405.04434; hf]  Note: the "160 routed" figure belongs to full V2; the
assignment line specifies "MoE 64e top-6" which matches V2-Lite, used here.
"""
from repro.configs.base import AttentionConfig, ModelConfig, MoEConfig

ARCH_ID = "deepseek-v2-lite-16b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="moe",
        num_layers=27,
        d_model=2048,
        d_ff=10944,                    # dense FFN width for first_dense_layers
        vocab_size=102400,
        attention=AttentionConfig(
            kind="mla",
            num_heads=16,
            num_kv_heads=16,
            head_dim=192,              # qk_nope + qk_rope
            kv_lora_rank=512,
            q_lora_rank=0,             # Lite: direct q projection
            qk_nope_head_dim=128,
            qk_rope_head_dim=64,
            v_head_dim=128,
            rope_theta=10000.0,
        ),
        moe=MoEConfig(
            num_experts=64,
            top_k=6,
            expert_ff=1408,
            num_shared=2,
            shared_ff=1408,
            first_dense_layers=1,
            transport="local",
        ),
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="moe",
        num_layers=3,
        d_model=64,
        d_ff=160,
        vocab_size=256,
        attention=AttentionConfig(
            kind="mla", num_heads=4, num_kv_heads=4, head_dim=24,
            kv_lora_rank=32, qk_nope_head_dim=16, qk_rope_head_dim=8,
            v_head_dim=16,
        ),
        moe=MoEConfig(
            num_experts=8, top_k=2, expert_ff=32, num_shared=2, shared_ff=32,
            first_dense_layers=1, transport="local",
        ),
        remat="none",
    )
