"""Configuration dataclasses for the repro framework.

Every assigned architecture is expressed as a ``ModelConfig``; shapes are
``ShapeConfig``; a dry-run/run cell is ``(ModelConfig, ShapeConfig, MeshConfig)``.

Configs are plain frozen dataclasses (no pydantic dependency in the hot path)
so they hash, compare, and round-trip to JSON trivially.
"""
from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field
from typing import Optional


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class AttentionConfig:
    """GQA / MQA / MHA / MLA attention configuration."""

    kind: str = "gqa"               # "gqa" | "mla"
    num_heads: int = 8
    num_kv_heads: int = 8
    head_dim: int = 64
    # Sliding-window attention. None => full attention on every layer.
    sliding_window: Optional[int] = None
    # local:global layer pattern, e.g. 5 => 5 sliding-window layers followed by
    # 1 full-attention layer (gemma3). 0 => all layers full attention.
    local_global_ratio: int = 0
    rope_theta: float = 10000.0
    # Fraction of head_dim that is rotated (stablelm uses 0.25).
    rotary_pct: float = 1.0
    # Multimodal rotary position embedding (qwen2-vl): 3 position streams.
    mrope: bool = False
    mrope_sections: tuple = (16, 24, 24)   # t/h/w split of half-dim
    # --- MLA (deepseek-v2) ---
    kv_lora_rank: int = 0
    q_lora_rank: int = 0            # 0 => no q compression (V2-Lite)
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0

    @property
    def q_dim(self) -> int:
        if self.kind == "mla":
            return self.num_heads * (self.qk_nope_head_dim + self.qk_rope_head_dim)
        return self.num_heads * self.head_dim

    @property
    def kv_groups(self) -> int:
        return max(1, self.num_heads // max(1, self.num_kv_heads))


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts FFN configuration (routed + shared experts)."""

    num_experts: int = 64
    top_k: int = 8
    expert_ff: int = 1024           # per-expert hidden width
    num_shared: int = 0             # always-on shared experts (deepseek)
    shared_ff: int = 0              # hidden width of the shared expert block
    # capacity factor for dropless-ish dispatch buffers
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01   # load-balancing aux loss
    router_z_coef: float = 1e-3     # router z-loss
    # Two-Chains jam transport mode: "local" ships tokens to experts (paper's
    # Local Function), "injected" ships expert weights to tokens (Injected
    # Function), "auto" picks per-step via core.costmodel.
    transport: str = "local"
    # First k layers use a dense FFN instead of MoE (deepseek-v2: 1).
    first_dense_layers: int = 0


# ---------------------------------------------------------------------------
# SSM (Mamba) / xLSTM
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class SSMConfig:
    """Mamba-style selective-state-space configuration (hymba)."""

    state_dim: int = 16
    conv_width: int = 4
    expand: int = 2                 # inner dim = expand * d_model (heads split)
    dt_rank: int = 0                # 0 => ceil(d_model/16)


@dataclass(frozen=True)
class XLSTMConfig:
    """xLSTM block stack configuration (mLSTM : sLSTM ratio)."""

    slstm_every: int = 8            # 1 sLSTM block per `slstm_every` blocks; 0 => none
    num_heads: int = 4
    proj_factor_mlstm: float = 2.0  # mLSTM up-projection factor
    proj_factor_slstm: float = 1.333
    conv_width: int = 4
    # chunk-parallel mLSTM chunk length (§Perf B1); sequences shorter than
    # 2*chunk (and decode) use the sequential scan
    chunk: int = 256


# ---------------------------------------------------------------------------
# Modality frontends (STUBS per assignment: input_specs provides embeddings)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class FrontendConfig:
    kind: str = "none"              # "none" | "audio_frames" | "vision_patches"
    feature_dim: int = 0            # dim of the precomputed frontend features
    num_patch_tokens: int = 0       # vlm: image tokens prepended per sequence


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"           # dense | moe | hybrid | ssm | audio | vlm
    num_layers: int = 4
    d_model: int = 256
    d_ff: int = 1024                # dense FFN width (0 => no FFN, e.g. xlstm)
    vocab_size: int = 32000
    attention: Optional[AttentionConfig] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    frontend: FrontendConfig = field(default_factory=FrontendConfig)
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    act: str = "silu"               # silu | gelu
    is_encoder: bool = False        # hubert: encoder-only, no causal mask/decode
    # hybrid: run attention and ssm in parallel inside one block (hymba)
    parallel_ssm_attn: bool = False
    # gated (SwiGLU-style, 3 matrices) vs classic 2-matrix MLP (GPT-BigCode)
    mlp_gated: bool = True
    dtype: str = "bfloat16"
    # logits soft-cap (gemma-style); 0 disables
    final_logit_softcap: float = 0.0
    remat: str = "full"             # "none" | "full" — activation checkpointing

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Analytic parameter count (total, incl. all experts)."""
        return _param_count(self, active_only=False)

    def active_param_count(self) -> int:
        """Parameters active per token (MoE: shared + top_k experts only)."""
        return _param_count(self, active_only=True)

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), default=str)


def _ffn_params(d_model: int, d_ff: int, gated: bool = True) -> int:
    # SwiGLU: gate + up + down; classic MLP: up + down
    return (3 if gated else 2) * d_model * d_ff


def _attn_params(cfg: ModelConfig) -> int:
    a = cfg.attention
    if a is None:
        return 0
    d = cfg.d_model
    if a.kind == "mla":
        qk_head = a.qk_nope_head_dim + a.qk_rope_head_dim
        p = d * a.num_heads * qk_head                      # q proj (no lora in Lite)
        p += d * (a.kv_lora_rank + a.qk_rope_head_dim)     # kv down + shared k_rope
        p += a.kv_lora_rank * a.num_heads * (a.qk_nope_head_dim + a.v_head_dim)
        p += a.num_heads * a.v_head_dim * d                # o proj
        return p
    hd = a.head_dim
    p = d * a.num_heads * hd                               # q
    p += 2 * d * a.num_kv_heads * hd                       # k, v
    p += a.num_heads * hd * d                              # o
    return p


def _layer_params(cfg: ModelConfig, layer_idx: int, active_only: bool) -> int:
    p = 0
    d = cfg.d_model
    if cfg.xlstm is not None:
        # mLSTM block: qkv + i/f gates + out, with up-projection
        inner = int(d * cfg.xlstm.proj_factor_mlstm)
        p += 2 * d * inner          # up/gate proj
        p += 3 * inner * inner // max(1, cfg.xlstm.num_heads)  # qkv (per-head block diag approx)
        p += inner * d              # down proj
        return p + 2 * d            # norms
    p += _attn_params(cfg)
    if cfg.ssm is not None:
        inner = cfg.ssm.expand * d
        p += d * 2 * inner          # in_proj (x, z)
        p += inner * cfg.ssm.conv_width
        dt_rank = cfg.ssm.dt_rank or -(-d // 16)
        p += inner * (dt_rank + 2 * cfg.ssm.state_dim) + dt_rank * inner
        p += inner * d              # out proj
    moe = cfg.moe
    use_moe = moe is not None and layer_idx >= (moe.first_dense_layers if moe else 0)
    if use_moe:
        n_e = (moe.num_shared + moe.top_k) if active_only else (moe.num_shared + moe.num_experts)
        shared = moe.num_shared * _ffn_params(d, moe.shared_ff or moe.expert_ff)
        routed_each = _ffn_params(d, moe.expert_ff)
        n_routed = moe.top_k if active_only else moe.num_experts
        p += shared + n_routed * routed_each + d * moe.num_experts  # + router
    elif cfg.d_ff > 0:
        p += _ffn_params(d, cfg.d_ff, cfg.mlp_gated)
    p += 2 * d                      # norms
    return p


def _param_count(cfg: ModelConfig, active_only: bool) -> int:
    p = cfg.vocab_size * cfg.d_model  # embed
    if not cfg.tie_embeddings:
        p += cfg.vocab_size * cfg.d_model
    if cfg.frontend.kind != "none":
        p += cfg.frontend.feature_dim * cfg.d_model
    for i in range(cfg.num_layers):
        p += _layer_params(cfg, i, active_only)
    p += cfg.d_model                 # final norm
    return p


# ---------------------------------------------------------------------------
# Shapes
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                       # "train" | "prefill" | "decode"


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


# ---------------------------------------------------------------------------
# Training / runtime config
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    # int8 DP-axis gradient compression with error feedback (Two-Chains-style
    # compact frames for the reduce).
    compress_grads: bool = False
    accum_steps: int = 1


@dataclass(frozen=True)
class ShardingConfig:
    """Maps logical tensor axes to mesh axes."""

    dp_axes: tuple = ("data",)      # batch / fsdp axes ("pod","data") multi-pod
    tp_axis: str = "model"          # heads / ffn / experts / vocab
    fsdp_params: bool = True        # shard d_model dims of params over dp axes
    seq_axis: Optional[str] = None  # long-context: shard seq/KV over this axis


@dataclass(frozen=True)
class RunConfig:
    model: ModelConfig = field(default_factory=ModelConfig)
    shape: ShapeConfig = field(default_factory=lambda: TRAIN_4K)
    optimizer: OptimizerConfig = field(default_factory=OptimizerConfig)
    sharding: ShardingConfig = field(default_factory=ShardingConfig)
    seed: int = 0
    checkpoint_every: int = 100
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep_checkpoints: int = 3
