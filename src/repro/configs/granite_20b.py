"""granite-20b: dense, 52L d_model=6144 48H (MQA kv=1) d_ff=24576 vocab=49152.

llama-architecture code model. [arXiv:2405.04324; hf]
"""
from repro.configs.base import AttentionConfig, ModelConfig

ARCH_ID = "granite-20b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="dense",
        num_layers=52,
        d_model=6144,
        d_ff=24576,
        vocab_size=49152,
        attention=AttentionConfig(
            kind="gqa", num_heads=48, num_kv_heads=1, head_dim=128,
            rope_theta=10000.0,
        ),
        act="gelu",
        mlp_gated=False,   # GPT-BigCode-style classic 2-matrix MLP
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="dense",
        num_layers=2,
        d_model=64,
        d_ff=192,
        vocab_size=256,
        attention=AttentionConfig(kind="gqa", num_heads=4, num_kv_heads=1, head_dim=16),
        act="gelu",
        mlp_gated=False,
        remat="none",
    )
