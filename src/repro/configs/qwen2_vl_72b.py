"""qwen2-vl-72b: VLM backbone, 80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064.

M-RoPE (3-D t/h/w rotary), dynamic resolution. [arXiv:2409.12191; hf]

The vision tower is a STUB per the assignment: ``input_specs()`` provides
precomputed merged patch embeddings (batch, n_img_tokens, 8192) plus 3-D
M-RoPE position ids; the backbone splices the image tokens in at fixed
positions. Backbone only.
"""
from repro.configs.base import AttentionConfig, FrontendConfig, ModelConfig

ARCH_ID = "qwen2-vl-72b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="vlm",
        num_layers=80,
        d_model=8192,
        d_ff=29568,
        vocab_size=152064,
        attention=AttentionConfig(
            kind="gqa", num_heads=64, num_kv_heads=8, head_dim=128,
            rope_theta=1_000_000.0, mrope=True, mrope_sections=(16, 24, 24),
        ),
        frontend=FrontendConfig(kind="vision_patches", feature_dim=8192,
                                num_patch_tokens=256),
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="vlm",
        num_layers=2,
        d_model=64,
        d_ff=128,
        vocab_size=256,
        attention=AttentionConfig(
            kind="gqa", num_heads=4, num_kv_heads=2, head_dim=16,
            mrope=True, mrope_sections=(2, 3, 3),
        ),
        frontend=FrontendConfig(kind="vision_patches", feature_dim=64,
                                num_patch_tokens=8),
        remat="none",
    )
