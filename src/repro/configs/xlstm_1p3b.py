"""xlstm-1.3b: ssm, 48L d_model=2048 4H d_ff=0 vocab=50304.

sLSTM + mLSTM blocks (xLSTM[7:1] — one sLSTM block per 8). No separate FFN
(mLSTM blocks carry their own up-projection). [arXiv:2405.04517; unverified]
"""
from repro.configs.base import ModelConfig, XLSTMConfig

ARCH_ID = "xlstm-1.3b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="ssm",
        num_layers=48,
        d_model=2048,
        d_ff=0,
        vocab_size=50304,
        attention=None,
        xlstm=XLSTMConfig(slstm_every=8, num_heads=4, proj_factor_mlstm=2.0),
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="ssm",
        num_layers=4,
        d_model=64,
        d_ff=0,
        vocab_size=256,
        attention=None,
        xlstm=XLSTMConfig(slstm_every=2, num_heads=4, proj_factor_mlstm=2.0),
        remat="none",
    )
