"""gemma3-4b: dense, 34L d_model=2560 8H (GQA kv=4) d_ff=10240 vocab=262144.

5:1 local(sliding-window):global attention pattern, 128k context.
[hf:google/gemma-3-1b-pt; unverified]
"""
from repro.configs.base import AttentionConfig, ModelConfig

ARCH_ID = "gemma3-4b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="dense",
        num_layers=34,
        d_model=2560,
        d_ff=10240,
        vocab_size=262144,
        attention=AttentionConfig(
            kind="gqa",
            num_heads=8,
            num_kv_heads=4,
            head_dim=256,
            sliding_window=1024,
            local_global_ratio=5,
            rope_theta=1_000_000.0,
        ),
        tie_embeddings=True,
        act="gelu",
        final_logit_softcap=30.0,
    )


def smoke() -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="dense",
        num_layers=6,             # keeps the 5:1 local/global pattern visible
        d_model=64,
        d_ff=128,
        vocab_size=256,
        attention=AttentionConfig(
            kind="gqa", num_heads=4, num_kv_heads=2, head_dim=16,
            sliding_window=8, local_global_ratio=5,
        ),
        tie_embeddings=True,
        act="gelu",
        final_logit_softcap=30.0,
        remat="none",
    )
