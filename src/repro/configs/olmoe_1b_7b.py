"""olmoe-1b-7b: MoE, 16L d_model=2048 16H (MHA kv=16) d_ff=1024(expert) vocab=50304.

64 experts, top-8 routing, no shared experts. [arXiv:2409.02060; hf]

This is the flagship Two-Chains arch: each expert is (3*2048*1024)*2B ≈ 12.6 MB
in bf16 — genuinely jam-sized, so injected-mode (weight-shipping) dispatch is
profitable for large token batches. ``transport="auto"`` lets core.costmodel
pick per step (the paper's auto-switch future work).
"""
from repro.configs.base import AttentionConfig, ModelConfig, MoEConfig

ARCH_ID = "olmoe-1b-7b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="moe",
        num_layers=16,
        d_model=2048,
        d_ff=0,
        vocab_size=50304,
        attention=AttentionConfig(
            kind="gqa", num_heads=16, num_kv_heads=16, head_dim=128,
            rope_theta=10000.0,
        ),
        moe=MoEConfig(
            num_experts=64, top_k=8, expert_ff=1024, num_shared=0,
            transport="auto",
        ),
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="moe",
        num_layers=2,
        d_model=64,
        d_ff=0,
        vocab_size=256,
        attention=AttentionConfig(kind="gqa", num_heads=4, num_kv_heads=4, head_dim=16),
        moe=MoEConfig(num_experts=8, top_k=2, expert_ff=32, transport="auto"),
        remat="none",
    )
