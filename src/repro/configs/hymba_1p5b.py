"""hymba-1.5b: hybrid, 32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001.

Parallel attention + mamba heads in every block, ssm_state=16.
[arXiv:2411.13676; hf]  Meta-tokens are omitted (orthogonal to the backbone
shape contract); attention uses a sliding window on most layers as in the
paper's hybrid-head config.
"""
from repro.configs.base import AttentionConfig, ModelConfig, SSMConfig

ARCH_ID = "hymba-1.5b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="hybrid",
        num_layers=32,
        d_model=1600,
        d_ff=5504,
        vocab_size=32001,
        attention=AttentionConfig(
            kind="gqa", num_heads=25, num_kv_heads=5, head_dim=64,
            sliding_window=1024, local_global_ratio=15,
            rope_theta=10000.0,
        ),
        ssm=SSMConfig(state_dim=16, conv_width=4, expand=2),
        parallel_ssm_attn=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="hybrid",
        num_layers=2,
        d_model=64,
        d_ff=128,
        vocab_size=256,
        attention=AttentionConfig(
            kind="gqa", num_heads=4, num_kv_heads=2, head_dim=16,
            sliding_window=8, local_global_ratio=1,
        ),
        ssm=SSMConfig(state_dim=4, conv_width=4, expand=2),
        parallel_ssm_attn=True,
        remat="none",
    )
